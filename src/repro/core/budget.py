"""Verify-server token-budget C derivation, adapted to TPU v5e (DESIGN §2).

The paper picks C by profiling an H100 (HBM memory headroom + latency
tolerance).  On TPU we derive C from first principles using the roofline
model of the batched verify forward pass:

* Each verify pass runs the target model over T = sum_i (S_i + 1) <= C + N
  tokens.  The matmul FLOPs grow ~ 2 * P * T (P = parameter count) while the
  weight traffic is ~ bytes(P) regardless of T — so small T is memory-bound
  and per-token cost is ~free until arithmetic intensity reaches the ridge
  point  I* = peak_flops / hbm_bw  (~240 FLOP/byte for v5e bf16).

* C* = the token count at the knee: beyond it, verify latency grows linearly
  with T and longer drafts stop being "free", so the budget should sit at
  the knee (same reasoning as the paper's "ideal number of tokens per
  forward pass to fully utilize both compute and memory bandwidth").

* A memory cap analogous to the paper's 75%-of-HBM rule bounds the KV-cache
  + activation footprint of the verify batch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """Per-chip TPU v5e constants used throughout the repo."""

    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    hbm_bytes: float = 16e9          # v5e HBM capacity
    ici_bw: float = 50e9             # bytes/s per link
    headroom: float = 0.75           # paper's <=75% memory rule


V5E = TpuSpec()

# Canonical jit-static verify-chunk widths (draft tokens per lane, i.e. the
# engine's per-round chunk is ``bucket + 1`` tokens wide).  The round-graph
# split compiles one draft scan / verify chunk / overlap draft-ahead per
# bucket, so every engine snaps its speculative shapes to this table —
# an ad-hoc s_max sweep then reuses a handful of compiled rounds instead
# of retracing per value.  ``benchmarks/serve_requests.py`` asserts a
# serving run never retraces a round phase more than once per bucket.
VERIFY_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)


def verify_bucket(s_max: int) -> int:
    """Smallest canonical bucket >= s_max (s_max itself beyond the table).

    The engine's REAL draft/verify shapes stay at its exact ``s_max`` (the
    recorded equivalence traces pin them); the bucket bounds the shapes of
    the speculative overlap draft-ahead and gives serve benchmarks a
    registry to assert compile counts against."""
    assert s_max >= 1, f"s_max must be >= 1, got {s_max}"
    for b in VERIFY_BUCKETS:
        if b >= s_max:
            return b
    return s_max


def ridge_tokens(bytes_per_param: int = 2, spec: TpuSpec = V5E) -> int:
    """Tokens per forward pass at the roofline ridge point.

    Per token the dense stack does ~2 FLOPs per parameter; the pass streams
    each parameter once (bytes_per_param).  Compute time >= weight-traffic
    time  <=>  2 * P * T / peak >= P * bpp / bw  <=>  T >= bpp/2 * peak/bw.
    """
    return int(round(bytes_per_param / 2 * spec.peak_flops / spec.hbm_bw))


def derive_budget(
    n_servers: int,
    params: float,
    kv_bytes_per_token: float,
    max_prefix_len: int,
    chips: int = 1,
    bytes_per_param: int = 2,
    spec: TpuSpec = V5E,
) -> int:
    """TPU-adapted C: min(roofline knee, memory-headroom cap) - N bonus slots.

    ``chips`` scales both capacity and bandwidth for a sharded verify server.
    """
    knee = ridge_tokens(bytes_per_param, spec) * chips
    weight_bytes = params * bytes_per_param
    free = spec.headroom * spec.hbm_bytes * chips - weight_bytes
    # every verified token needs a KV slot against the longest prefix
    mem_cap = free / max(kv_bytes_per_token * (max_prefix_len + 1), 1.0) \
        if free > 0 else 0
    c = int(max(min(float(knee), mem_cap) - n_servers, n_servers))
    return c
