"""Online estimators for acceptance rates and goodput (paper Eqs. 3-4).

The verification server maintains, per draft server i:

* smoothed acceptance rate  alpha_hat_i(t)  (Eq. 3):
      alpha_hat(t) = (1-eta) alpha_hat(t-1)
                   + eta * mean_j min(1, p_j(s_j) / q_{i,j}(s_j))
  where the mean runs over the S_i(t) verified draft positions.

* smoothed goodput  X_i^beta(t)  (Eq. 4):
      X(t) = (1-beta) X(t-1) + beta x_i(t)
  with x_i(t) the realized goodput (accepted tokens + 1 correction/bonus).

Assumption 3 of the paper takes decaying step sizes eta = O(1/t^a),
beta = O(1/t^b) with 0.5 < a,b <= 1 and eta/beta -> 0; we support both the
constant-step regime used in the experiments (beta = 0.5) and the decaying
schedules used by the theory.

Staleness contract (the overlap round graph relies on this): ``update``
is a pure fold over per-round observations, so the engine may consume an
EstimatorState one round LATE without touching this module — the
overlapped draft-ahead for round t+1 plans its budgets from the state as
of round t-1's update (round t's observations have not landed when the
ahead dispatches), while the real round t+1 re-plans from the fully
updated state.  Both reads see internally-consistent (alpha_hat, X^beta,
t) snapshots; the EWMA itself is never forked or partially applied.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

Array = jnp.ndarray


class EstimatorState(NamedTuple):
    alpha_hat: Array   # f32[N] smoothed acceptance rates, in (0,1)
    goodput: Array     # f32[N] smoothed goodput X^beta
    t: Array           # i32[]  round counter


@dataclasses.dataclass(frozen=True)
class StepSchedule:
    """eta(t) / beta(t) schedules.  exponent=0 -> constant base step."""

    base: float
    exponent: float = 0.0  # paper Assumption 3 wants (0.5, 1]
    t0: float = 1.0        # horizon shift so t=0 is well defined

    def __call__(self, t: Array) -> Array:
        if self.exponent == 0.0:
            return jnp.asarray(self.base)
        return self.base / (jnp.asarray(t, jnp.float32) + self.t0) ** self.exponent


@dataclasses.dataclass(frozen=True)
class GoodputEstimator:
    """Stateless transition function for (alpha_hat, X^beta)."""

    eta: StepSchedule = StepSchedule(0.3)
    beta: StepSchedule = StepSchedule(0.5)
    alpha_init: float = 0.5
    goodput_init: float = 1.0

    def init(self, n: int) -> EstimatorState:
        return EstimatorState(
            alpha_hat=jnp.full((n,), self.alpha_init, jnp.float32),
            goodput=jnp.full((n,), self.goodput_init, jnp.float32),
            t=jnp.zeros((), jnp.int32),
        )

    def update(self, state: EstimatorState, accept_ratio_sum: Array,
               S: Array, realized_goodput: Array) -> EstimatorState:
        """One verification round.

        accept_ratio_sum: f32[N] sum over verified positions of
            min(1, p_j(s_j)/q_{i,j}(s_j)) for server i (only the first S_i
            positions of the padded verify batch contribute).
        S:               i32[N] this round's draft lengths (Eq. 3 divides by S_i).
        realized_goodput: f32[N] x_i(t) = accepted + 1.
        """
        t = state.t
        eta = self.eta(t).astype(jnp.float32)
        beta = self.beta(t).astype(jnp.float32)

        s_f = jnp.maximum(S.astype(jnp.float32), 1.0)
        empirical = jnp.clip(accept_ratio_sum / s_f, 0.0, 1.0)
        # Servers scheduled S_i = 0 this round contribute no observation —
        # hold BOTH estimates (the paper's Eq. 3 is only defined for S_i>0,
        # and letting the Eq. 4 EMA absorb x_i from a round the server never
        # drafted in would silently drag an idle server's goodput toward
        # the bonus token's x_i = 1, distorting the fairness weight
        # w_i = dU/dx(X_i) it re-enters the scheduler with).
        observed = S > 0
        alpha_new = (1.0 - eta) * state.alpha_hat + eta * empirical
        alpha_hat = jnp.where(observed, alpha_new, state.alpha_hat)

        goodput_new = (1.0 - beta) * state.goodput + beta * realized_goodput
        goodput = jnp.where(observed, goodput_new, state.goodput)
        return EstimatorState(alpha_hat=alpha_hat, goodput=goodput, t=t + 1)
