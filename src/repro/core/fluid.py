"""Fluid-limit machinery (paper §III-D and Appendix).

Two independent computations of the optimal goodput x* of problem (1):

1. ``optimal_goodput`` — closed-form-ish water-filling.  For log utility the
   achievable region X is the hull of {mu(k)} over the integer budget
   simplex; since mu_i(S) = 1 + a + ... + a^S is concave increasing in S,
   X = { x : x_i <= mu_bar_i(s_i),  sum_i s_i <= C, s >= 0 }
   with mu_bar the piecewise-linear interpolation of mu at integers
   (time-sharing two adjacent integer allocations realizes any fractional
   s).  max sum_i log mu_bar_i(s_i) s.t. sum s_i = C is separable-concave:
   KKT gives, on segment s = k + f (f in [0,1]), the stationarity condition
   d/ds log mu_bar = a^(k+1) / (mu(k) + f a^(k+1)) = lam, i.e.
   f = 1/lam - mu(k)/a^(k+1); bisect the price lam so sum_i s_i(lam) = C.

2. ``integrate_fluid`` — integrates the Lemma-2 fluid dynamics
        x'(t) = v(t) - x(t),
        v(t) in argmax_{v in X} sum_i (1/x_i) v_i
   where the argmax is computed by the *actual* GOODSPEED-SCHED solver
   (with true alphas), i.e. the same Frank-Wolfe-style vertex oracle the
   discrete system uses.  Theorem 3 says x(t) -> x*; the tests check both
   computations agree, which ties the implementation to the theory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.goodput import expected_goodput
from repro.core.scheduler import solve_threshold

Array = jnp.ndarray
_EPS = 1e-9


def _claims_fractional(lam: Array, alpha: Array, C: int) -> Array:
    """s_i(lam): fractional slots claimed by each client at price lam."""
    a = jnp.clip(alpha, _EPS, 1.0 - 1e-6)
    ks = jnp.arange(C + 1, dtype=jnp.float32)                 # segments k
    mu_k = expected_goodput(ks[None, :], a[:, None])          # [N, C+1]
    ga = a[:, None] ** (ks[None, :] + 1.0)                    # segment slope
    # stationarity f = 1/lam - mu(k)/a^(k+1) on segment k, clipped to [0,1]
    f = 1.0 / jnp.maximum(lam, _EPS) - mu_k / jnp.maximum(ga, _EPS)
    f = jnp.clip(f, 0.0, 1.0)
    # derivative of log mu_bar at segment start: a^(k+1)/mu(k); client walks
    # fully through segments whose START derivative >= lam is partial where
    # it straddles.  Equivalent: s_i = sum_k [deriv_start_k >= lam ? (f if
    # deriv_end_k < lam else 1) : 0].  deriv decreasing across segments.
    d_start = ga / jnp.maximum(mu_k, _EPS)
    d_end = ga / jnp.maximum(mu_k + ga, _EPS)
    full = d_end >= lam
    partial = (d_start >= lam) & (d_end < lam)
    s = jnp.sum(jnp.where(full, 1.0, jnp.where(partial, f, 0.0)), axis=-1)
    return jnp.minimum(s, float(C))


@functools.partial(jax.jit, static_argnames=("C", "iters"))
def optimal_goodput(alpha: Array, C: int, iters: int = 80):
    """Water-filling solution (s*, x*) of max sum log mu_bar(s) s.t. sum s = C."""
    a = jnp.clip(alpha, _EPS, 1.0 - 1e-6)
    lo = jnp.asarray(1e-8)
    hi = jnp.asarray(1.0)  # max derivative: a/1 <= 1

    def body(_, lohi):
        lo, hi = lohi
        mid = jnp.sqrt(lo * hi)  # geometric bisection (price spans decades)
        tot = jnp.sum(_claims_fractional(mid, a, C))
        # tot decreasing in lam: too many slots -> raise price
        return jnp.where(tot > C, mid, lo), jnp.where(tot > C, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    s_star = _claims_fractional(hi, a, C)
    # renormalize tiny bisection residue onto clients proportionally
    s_star = s_star * (C / jnp.maximum(jnp.sum(s_star), _EPS))
    x_star = expected_goodput(s_star, a)
    return s_star, x_star


@functools.partial(jax.jit, static_argnames=("C", "steps"))
def integrate_fluid(alpha: Array, C: int, x0: Array, steps: int = 400,
                    dt: float = 0.05) -> Array:
    """Euler-integrate x' = v - x with v from the GOODSPEED-SCHED oracle.

    Returns the trajectory x[t] (f32[steps, N]).  Lemma 2's v(t) maximizes
    sum_i v_i / x_i over X; the maximum over a polytope is attained at a
    vertex mu(k), and picking k is exactly GOODSPEED-SCHED with weights
    1/x_i — so we reuse solve_threshold as the vertex oracle.
    """
    a = jnp.clip(alpha, _EPS, 1.0 - 1e-6)

    def step(x, _):
        w = 1.0 / jnp.maximum(x, 1e-6)
        S = solve_threshold(a, w, C).S
        v = expected_goodput(S, a)
        x_new = x + dt * (v - x)
        return x_new, x_new

    _, traj = jax.lax.scan(step, x0, None, length=steps)
    return traj
