"""Algorithm 1 — the GoodSpeed round loop, bound into a jit-able simulator.

The coordinator owns the verification-server state (estimator + current
allocation) and advances one *round* per call:

  (1) draft servers generate S_i(t) tokens           [done by the caller or
  (2) drafts are sent to the verifier                 the synthetic world]
  (3) batching
  (4) rejection-sampling verification                -> speculative.verify
      computing x_i(t), updating alpha_hat (Eq.3) and X^beta (Eq.4)
  (5) GOODSPEED-SCHED solve for S(t+1)               -> scheduler.solve_*
  (6) allocation broadcast back

Two drivers are provided:

* ``simulate_analytic`` — the acceptance channel is sampled directly from
  its law (truncated geometric with the true time-varying alpha_i(t)); the
  workload is an arbitrary alpha trajectory.  This is the fast path used
  for the Fig. 2/4 convergence experiments (thousands of rounds, jit'd
  scan).

* ``run_round_logits`` — the faithful path: takes real draft/target logits,
  runs full rejection-sampling verification, feeds Eq.3 with the actual
  min(1, p/q) indicators.  serving/engine.py drives this with transformer
  models; tests drive it with synthetic logit pairs of controlled TV
  distance.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.estimator import EstimatorState, GoodputEstimator
from repro.core.goodput import expected_goodput
from repro.core.latency import LatencyModel
from repro.core.scheduler import fixed_s, random_s, solve_threshold
from repro.core.speculative import VerifyResult, verify
from repro.core.utility import UtilitySpec

Array = jnp.ndarray


class RoundState(NamedTuple):
    est: EstimatorState
    S: Array            # i32[N] current allocation (drafted this round)
    key: Array
    remaining: Array    # i32[N] tokens left before each request completes


class RoundLog(NamedTuple):
    S: Array               # allocation used this round
    realized: Array        # x_i(t) tokens emitted
    goodput_est: Array     # X^beta after update
    alpha_hat: Array       # after update
    utility: Array         # U(X^beta)
    wall: Array            # (total, receive, verify, send) seconds


@dataclasses.dataclass(frozen=True)
class Coordinator:
    n: int
    C: int
    estimator: GoodputEstimator = GoodputEstimator()
    utility: UtilitySpec = UtilitySpec(alpha=1.0)
    latency: LatencyModel = LatencyModel()
    policy: str = "goodspeed"   # goodspeed | fixed | random
    vocab: int = 32000          # used only by the latency payload model
    # paper §IV-A2: requests have a max token length (50 or 150); tokens
    # drafted past a request's completion are wasted verification work.
    # GoodSpeed passes remaining-length caps to the solver (s_max);
    # Fixed-S / Random-S ignore them — the source of the paper's ~5%
    # verification-time saving.  0 disables completion tracking.
    max_new_tokens: int = 0

    def init(self, key: Array) -> RoundState:
        est = self.estimator.init(self.n)
        S0 = fixed_s(self.n, self.C)  # warm start: uniform
        rem = jnp.full((self.n,), max(self.max_new_tokens, 1), jnp.int32)
        return RoundState(est=est, S=S0, key=key, remaining=rem)

    # -- step (5): next allocation under the configured policy -------------
    def schedule(self, est: EstimatorState, key: Array,
                 remaining: Array | None = None) -> Array:
        if self.policy == "goodspeed":
            w = self.utility.grad(est.goodput)
            cap = None
            if self.max_new_tokens > 0 and remaining is not None:
                cap = jnp.maximum(remaining, 0)
            return solve_threshold(est.alpha_hat, w, self.C, s_max=cap).S
        if self.policy == "fixed":
            return fixed_s(self.n, self.C)
        if self.policy == "random":
            return random_s(key, self.n, self.C)
        raise ValueError(f"unknown policy {self.policy!r}")

    # -- steps (3)(4)(5)(6) given verification outcomes ---------------------
    def finish_round(self, state: RoundState, accept_ratio_sum: Array,
                     realized: Array, key_sched: Array,
                     jitter: Array) -> tuple[RoundState, RoundLog]:
        remaining = state.remaining
        if self.max_new_tokens > 0:
            # tokens past request completion are wasted (not goodput)
            realized = jnp.minimum(realized,
                                   remaining.astype(realized.dtype))
            remaining = remaining - realized.astype(jnp.int32)
            # completed requests are immediately replaced (continuous batching)
            remaining = jnp.where(remaining <= 0, self.max_new_tokens,
                                  remaining)
        est = self.estimator.update(state.est, accept_ratio_sum, state.S,
                                    realized)
        S_next = self.schedule(est, key_sched, remaining)
        total, (r, v, s) = self.latency.round_time(
            state.S, realized, self.vocab, jitter)
        log = RoundLog(S=state.S, realized=realized, goodput_est=est.goodput,
                       alpha_hat=est.alpha_hat, utility=self.utility.value(est.goodput),
                       wall=jnp.stack([total, r, v, s]))
        return RoundState(est=est, S=S_next, key=state.key,
                          remaining=remaining), log

    # -- faithful round with explicit logits --------------------------------
    def run_round_logits(self, state: RoundState, draft_tokens: Array,
                         q_logits: Array, p_logits: Array
                         ) -> tuple[RoundState, RoundLog, VerifyResult]:
        key, k_verify, k_sched, k_jit = jax.random.split(state.key, 4)
        res = verify(k_verify, draft_tokens, q_logits, p_logits, state.S)
        jitter = jax.random.uniform(k_jit, (self.n,), minval=-1.0, maxval=1.0)
        new_state, log = self.finish_round(
            state._replace(key=key), res.accept_ratio_sum,
            res.num_emitted.astype(jnp.float32), k_sched, jitter)
        return new_state, log, res

    # -- analytic acceptance channel ----------------------------------------
    def _analytic_round(self, state: RoundState, alpha_true: Array
                        ) -> tuple[RoundState, RoundLog]:
        key, k_acc, k_sched, k_jit, k_ind = jax.random.split(state.key, 5)
        S = state.S
        s_max = self.C  # padded width for the uniform draws
        u = jax.random.uniform(k_acc, (self.n, s_max))
        pos = jnp.arange(s_max)[None, :]
        in_draft = pos < S[:, None]
        accept = jnp.where(in_draft, u <= alpha_true[:, None], False)
        rejected = ~accept
        any_rej = jnp.any(rejected, axis=-1)
        m = jnp.where(any_rej, jnp.argmax(rejected, axis=-1), s_max)
        m = jnp.minimum(m, S)
        realized = (m + 1).astype(jnp.float32)
        # Eq.3 indicators: E[min(1,p/q)] = alpha; model the per-position
        # indicator noise as Beta-like around alpha via bounded uniform.
        noise = 0.1 * jax.random.uniform(k_ind, (self.n, s_max), minval=-1., maxval=1.)
        ind = jnp.clip(alpha_true[:, None] + noise, 0.0, 1.0)
        ratio_sum = jnp.sum(jnp.where(in_draft, ind, 0.0), axis=-1)
        jitter = jax.random.uniform(k_jit, (self.n,), minval=-1.0, maxval=1.0)
        return self.finish_round(state._replace(key=key), ratio_sum,
                                 realized, k_sched, jitter)

    def simulate_analytic(self, key: Array, alpha_traj: Array
                          ) -> tuple[RoundState, RoundLog]:
        """Scan the analytic round over alpha_traj f32[T, N]; returns stacked
        RoundLog over T rounds."""
        state = self.init(key)

        def step(st, alpha_t):
            st, log = self._analytic_round(st, alpha_t)
            return st, log

        return jax.lax.scan(step, state, alpha_traj)


@functools.partial(jax.jit, static_argnames=("coord",))
def simulate(coord: Coordinator, key: Array, alpha_traj: Array):
    return coord.simulate_analytic(key, alpha_traj)
