"""GOODSPEED-SCHED — the paper's gradient scheduling algorithm (Eq. 5).

At every round t the verification server solves

    max_{S}  sum_i  w_i * mu(S_i; alpha_hat_i)      (w_i = dU_i/dx (X_i^beta))
    s.t.     sum_i S_i <= C,   S_i in Z+ (optionally S_i <= S_max)

with mu(S; a) = (1 - a^(S+1)) / (1 - a)  (goodput.py).  Because the marginal
value of the s-th slot of client i is  g_i(s) = w_i * a_i^s,  positive and
strictly decreasing in s, the objective is separable-concave on the integer
simplex and **greedy marginal allocation is exactly optimal** (the classic
incremental argument for concave resource allocation; this is also why
Stolyar's gradient scheduling reduces to a simple rule here).

Two solvers are provided and tested equivalent:

* ``solve_greedy``     — exact: C rounds of argmax over the N current
                         marginals (lax.while_loop / fori_loop).  O(C·N).
* ``solve_threshold``  — exact & fast: bisect a price theta on the marginal
                         value; each client claims S_i(theta) = #{s >= 1 :
                         w_i a_i^s >= theta} slots in closed form, then the
                         leftover budget (ties at the threshold) is assigned
                         greedily.  O(N log(1/eps) + leftover).  This is the
                         production solver: fully vectorized over clients and
                         trivially shard-able.

Also implements the paper's baselines: ``fixed_s`` (S_i = C/N) and
``random_s`` (random split of the budget).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.goodput import expected_goodput

Array = jnp.ndarray

_EPS = 1e-9


class SchedulerOutput(NamedTuple):
    S: Array          # int32[N] draft-length allocation, sum <= C
    objective: Array  # scalar: sum_i w_i * mu(S_i; alpha_i)
    price: Array      # scalar: final threshold price (threshold solver; 0 for greedy)


def _clip_inputs(alpha: Array, weights: Array):
    a = jnp.clip(alpha, _EPS, 1.0 - 1e-6)
    w = jnp.maximum(weights, 0.0)
    return a, w


def objective_value(S: Array, alpha: Array, weights: Array) -> Array:
    """sum_i w_i mu(S_i; alpha_i) — the Eq. 5 objective."""
    return jnp.sum(weights * expected_goodput(S, alpha))


# ---------------------------------------------------------------------------
# Exact greedy solver (reference; O(C N))
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("C",))
def solve_greedy(alpha: Array, weights: Array, C: int,
                 s_max: Array | None = None) -> SchedulerOutput:
    """Allocate C slots one at a time to the largest current marginal."""
    a, w = _clip_inputs(alpha, weights)
    n = a.shape[0]
    cap = jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32) if s_max is None \
        else jnp.asarray(s_max, jnp.int32)

    def body(_, S):
        # marginal of giving one more slot to i: w_i * a_i^(S_i + 1)
        g = w * a ** (S.astype(a.dtype) + 1.0)
        g = jnp.where(S >= cap, -jnp.inf, g)
        i = jnp.argmax(g)
        # if best marginal is 0 (w==0 exactly) still allocate deterministically;
        # objective unaffected.  Guard the all-capped case.
        take = jnp.where(jnp.isfinite(g[i]), 1, 0).astype(jnp.int32)
        return S.at[i].add(take)

    S = jax.lax.fori_loop(0, C, body, jnp.zeros((n,), jnp.int32))
    return SchedulerOutput(S, objective_value(S, a, w), jnp.zeros(()))


# ---------------------------------------------------------------------------
# Threshold / price solver (production; vectorized)
# ---------------------------------------------------------------------------

def _claims(theta: Array, a: Array, w: Array, cap: Array) -> Array:
    """S_i(theta) = #{ s >= 1 : w_i a_i^s >= theta }, capped.

    w a^s >= theta  <=>  s <= log(theta / w) / log(a)      (log a < 0)
    """
    t = jnp.maximum(theta, _EPS)
    ratio = jnp.log(t / jnp.maximum(w, _EPS)) / jnp.log(a)  # may be negative
    s = jnp.floor(ratio + 1e-12)
    s = jnp.where(w * a >= t, jnp.maximum(s, 1.0), jnp.minimum(s, 0.0))
    s = jnp.clip(s, 0.0, cap.astype(s.dtype))
    return s.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("C", "iters"))
def solve_threshold(alpha: Array, weights: Array, C: int,
                    s_max: Array | None = None, iters: int = 64) -> SchedulerOutput:
    """Bisection on the slot price theta + greedy remainder fill (exact)."""
    a, w = _clip_inputs(alpha, weights)
    n = a.shape[0]
    cap = jnp.full((n,), C, jnp.int32) if s_max is None \
        else jnp.minimum(jnp.asarray(s_max, jnp.int32), C)

    g_hi = jnp.max(w * a)  # largest possible marginal

    # Bisect theta in [0, g_hi]: total claims are non-increasing in theta.
    # Invariant: claims(hi) <= C <= claims(lo) (lo=0 claims cap-total or C+).
    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        tot = jnp.sum(_claims(mid, a, w, cap))
        return jnp.where(tot > C, mid, lo), jnp.where(tot > C, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.zeros(()), g_hi + _EPS))
    S = _claims(hi, a, w, cap)

    # Leftover budget from discreteness/ties: hand out greedily.  The number
    # of leftover slots is at most N after tight bisection (each client can
    # straddle the price by < 1 slot), but we bound the loop by C for safety.
    def cond(state):
        S, r = state
        return r > 0

    def fill(state):
        S, r = state
        g = w * a ** (S.astype(a.dtype) + 1.0)
        g = jnp.where(S >= cap, -jnp.inf, g)
        i = jnp.argmax(g)
        ok = jnp.isfinite(g[i])
        S = S.at[i].add(jnp.where(ok, 1, 0).astype(jnp.int32))
        r = jnp.where(ok, r - 1, 0)
        return S, r

    S, _ = jax.lax.while_loop(cond, fill, (S, jnp.asarray(C, jnp.int32) - jnp.sum(S)))
    return SchedulerOutput(S, objective_value(S, a, w), hi)


# ---------------------------------------------------------------------------
# Paper baselines
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("C", "n"))
def fixed_s(n: int, C: int) -> Array:
    """Fixed-S baseline (uniform; paper §IV-B2): S_i = C // N, with the
    C % N remainder handed deterministically to the first C % N servers so
    the baseline spends its whole verify budget (sum(S) == C) instead of
    silently dropping up to N-1 slots every round."""
    base = jnp.full((n,), C // n, jnp.int32)
    return base + (jnp.arange(n) < C % n).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("C", "n"))
def random_s(key: Array, n: int, C: int) -> Array:
    """Random-S baseline: random composition of the budget across clients
    (uniform over the simplex grid via multinomial thinning)."""
    logits = jnp.zeros((n,))
    # draw C slot owners i.i.d. uniformly — a random allocation summing to C
    owners = jax.random.categorical(key, logits, shape=(C,))
    return jnp.zeros((n,), jnp.int32).at[owners].add(1)


def _capped(S: Array, s_max: Array | None) -> Array:
    return S if s_max is None else jnp.minimum(S, jnp.asarray(s_max, jnp.int32))


# ---------------------------------------------------------------------------
# Per-server lane splitter (multi-request draft servers)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("level_max",))
def split_lanes(S: Array, lane_caps: Array, level_max: int) -> Array:
    """Divide each server's budget across its request lanes (water-filling).

    GOODSPEED-SCHED stays at SERVER granularity — the paper's fairness unit
    — and this splitter turns the per-server allocation ``S`` (i32[N]) into
    per-lane draft lengths (i32[N, R]) against the lanes' remaining caps
    ``lane_caps`` (i32[N, R], already min'd with the engine's s_max, which
    is ``level_max``).  Completion-aware and deterministic:

      * idle lanes (cap 0) get nothing;
      * allocation water-fills — as even as the caps allow (any two lanes
        differ by at most 1 unless one is sitting at its cap);
      * the sub-level remainder goes to the lowest-indexed eligible lanes;
      * per lane out[i, r] <= lane_caps[i, r], and per server
        sum_r out[i, r] == min(S[i], sum_r lane_caps[i, r]).

    The water level L* is found in closed form: fill(L) = sum_r
    min(cap_r, L) is non-decreasing in L, so L* is the first level whose
    fill reaches the target — a [N, R, level_max+1] broadcast, no loop.
    """
    lane_caps = jnp.asarray(lane_caps, jnp.int32)
    target = jnp.minimum(jnp.asarray(S, jnp.int32),
                         lane_caps.sum(axis=1))                  # i32[N]
    levels = jnp.arange(level_max + 1, dtype=jnp.int32)          # [L+1]
    fill = jnp.minimum(lane_caps[:, :, None],
                       levels[None, None, :]).sum(axis=1)        # [N, L+1]
    lstar = jnp.sum(fill < target[:, None], axis=1)              # i32[N]
    base = jnp.minimum(lane_caps, jnp.maximum(lstar - 1, 0)[:, None])
    rem = target - base.sum(axis=1)          # 0 <= rem <= #lanes at >= L*
    elig = lane_caps >= lstar[:, None]
    rank = jnp.cumsum(elig.astype(jnp.int32), axis=1) - 1
    return base + (elig & (rank < rem[:, None])).astype(jnp.int32)


def plan_budgets(sched, alpha_hat: Array, weights: Array, C: int,
                 lane_cap: Array, s_max: int, key: Array | None = None
                 ) -> Array:
    """One round's per-LANE draft budgets: GOODSPEED-SCHED at server
    granularity (the paper's fairness unit) water-filled across each
    server's live lanes.  ``lane_cap`` is i32[N, R] (remaining caps
    already min'd with ``s_max``); returns i32[N*R] server-major.

    Extracted from the engine's round step (0) so BOTH planning lanes of
    the round graph share it: the synchronous/reconciled round plans from
    the CURRENT estimator state, while overlap mode's draft-ahead plans
    round t+1 from the state BEFORE round t's update (round t-1's
    observations — the estimator update lands one round late relative to
    the speculative dispatch; see serving.engine)."""
    srv_cap = lane_cap.sum(axis=1)                    # i32[N]
    S_srv = sched(alpha_hat, weights, C, key=key, s_max=srv_cap)
    S_srv = jnp.where(srv_cap > 0, S_srv, 0)
    return split_lanes(S_srv, lane_cap, s_max).reshape(-1)


def make_scheduler(name: str):
    """Factory used by the serving engine; returns
    ``fn(alpha, weights, C, key=None, s_max=None) -> S``.

    The exact solvers (goodspeed/greedy) treat ``s_max`` as a per-client
    constraint INSIDE the optimization — a zero-cap (idle) client gets
    S_i = 0 and its share of the budget flows to the others.  The paper
    baselines ignore the budget shape by definition, so their allocations
    are clipped to the caps after the fact (an idle row still ends at 0)."""
    name = name.lower()
    if name in ("goodspeed", "gradient", "threshold"):
        return lambda alpha, weights, C, key=None, s_max=None: \
            solve_threshold(alpha, weights, C, s_max).S
    if name == "greedy":
        return lambda alpha, weights, C, key=None, s_max=None: \
            solve_greedy(alpha, weights, C, s_max).S
    if name in ("fixed", "fixed-s"):
        return lambda alpha, weights, C, key=None, s_max=None: \
            _capped(fixed_s(alpha.shape[0], C), s_max)
    if name in ("random", "random-s"):
        return lambda alpha, weights, C, key=None, s_max=None: \
            _capped(random_s(key, alpha.shape[0], C), s_max)
    raise ValueError(f"unknown scheduler {name!r}")
