"""Discrete-event wall-time model for the GoodSpeed round loop (Fig. 3).

The paper decomposes each round's wall time into
  (1) receiving time   — verify server waits for the SLOWEST draft server
                         (draft generation is sequential in S_i) plus the
                         uplink transfer of tokens + draft distributions;
  (2) verification time — batched target forward over sum_i (S_i+1) tokens;
  (3) sending time      — accepted tokens + next allocation downlink
                         (<0.1% of the total in the paper).

This container has no real network or GPUs, so we model each component from
hardware constants; the *relative* effects the paper reports (Random-S /
GoodSpeed pay a receive-time penalty from ragged S_i; GoodSpeed wins ~5%
verification time via load balancing) emerge from the same mechanics.

All functions are jnp-pure so the simulator can jit over rounds.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.budget import TpuSpec, V5E

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    # Draft servers (edge, L4-class in the paper): sequential decode rate.
    draft_tok_s: float = 120.0          # tokens/s autoregressive drafting
    draft_tok_s_jitter: float = 0.15    # per-round multiplicative jitter

    # Links (edge uplink): draft tokens ship with their distributions.
    uplink_bytes_s: float = 12.5e6      # 100 Mbit/s
    downlink_bytes_s: float = 12.5e6
    rtt_s: float = 0.02                 # per-message overhead
    probs_topk: int = 0                 # 0 = full distribution (paper);
                                        # k>0 = beyond-paper top-k truncation
    bytes_per_prob: int = 2             # fp16 probabilities
    bytes_per_token: int = 4

    # Verify server (H100 in the paper, TPU v5e here).
    verify_params: float = 14e9         # target model parameter count
    verify_chips: int = 1
    bytes_per_param: int = 2
    spec: TpuSpec = V5E

    # ---- components -------------------------------------------------------
    def draft_time(self, S: Array, jitter: Array) -> Array:
        """Sequential generation of S_i tokens at the edge. jitter ~ U[-1,1]."""
        rate = self.draft_tok_s * (1.0 + self.draft_tok_s_jitter * jitter)
        return S.astype(jnp.float32) / jnp.maximum(rate, 1.0)

    def uplink_payload(self, S: Array, vocab: int) -> Array:
        k = self.probs_topk if self.probs_topk > 0 else vocab
        per_tok = self.bytes_per_token + k * self.bytes_per_prob \
            + (self.probs_topk > 0) * k * 4  # top-k also ships indices
        return S.astype(jnp.float32) * per_tok

    def server_arrival_times(self, S: Array, vocab: int, jitter: Array,
                             lanes: int = 1, slow: Array = None,
                             uplink: Array = None):
        """Per-SERVER chunk arrival times: (arrival f32[N], live bool[N]).

        ``lanes`` > 1 groups the [N*R] per-lane rows server-major: a
        server's lanes decode in ONE batched forward (draft time = its
        slowest lane) but share the server's uplink (payloads SUM over
        its lanes before the transfer-time division).

        ``slow`` / ``uplink`` are optional f32[N] fault multipliers
        (``serving.faults.RoundFaults``): a straggler's draft time and a
        degraded link's transfer time scale by them (1.0 = nominal; the
        None path is bit-identical to the historical receive-time math).
        The engine compares ``arrival`` against the verify deadline to
        decide per-server misses."""
        draft = self.draft_time(S, jitter)
        payload = self.uplink_payload(S, vocab)
        live = S > 0
        if lanes > 1:
            n = S.shape[0] // lanes
            draft = jnp.max(draft.reshape(n, lanes), axis=1)
            payload = payload.reshape(n, lanes).sum(axis=1)
            live = live.reshape(n, lanes).any(axis=1)
        if slow is not None:
            draft = draft * slow
        xfer = payload / self.uplink_bytes_s
        if uplink is not None:
            xfer = xfer * uplink
        return draft + xfer + self.rtt_s, live

    def receive_time(self, S: Array, vocab: int, jitter: Array,
                     lanes: int = 1, slow: Array = None,
                     uplink: Array = None) -> Array:
        """Batch assembly = max over LIVE servers of (draft + uplink),
        optionally under per-server fault multipliers (see
        ``server_arrival_times``)."""
        per, live = self.server_arrival_times(S, vocab, jitter, lanes=lanes,
                                              slow=slow, uplink=uplink)
        return jnp.max(jnp.where(live, per, 0.0))

    def verify_time(self, S: Array) -> Array:
        """Roofline time of one batched verify pass over T = sum(S_i + 1)."""
        T = jnp.sum(jnp.where(S > 0, S + 1, 0)).astype(jnp.float32)
        flops = 2.0 * self.verify_params * T
        weight_bytes = self.verify_params * self.bytes_per_param
        t_compute = flops / (self.spec.peak_flops * self.verify_chips)
        t_memory = weight_bytes / (self.spec.hbm_bw * self.verify_chips)
        return jnp.maximum(t_compute, t_memory)

    def send_time(self, num_emitted: Array) -> Array:
        """Serialization+enqueue only: the downlink send is asynchronous
        (fire-and-forget), so no RTT is charged — matching the paper's
        observation that sending is <0.1% of wall time."""
        payload = jnp.sum(num_emitted).astype(jnp.float32) \
            * self.bytes_per_token + 8.0 * num_emitted.shape[0]  # S(t+1) ints
        return payload / self.downlink_bytes_s

    def round_time(self, S: Array, num_emitted: Array, vocab: int,
                   jitter: Array, lanes: int = 1, slow: Array = None,
                   uplink: Array = None, deadline: Array = None):
        """S / num_emitted / jitter are per-row ([N] servers, or [N*R]
        server-major lane rows with ``lanes`` set).  Verify and send cost
        every lane's tokens (sums over rows already); only receive needs
        the lane grouping (shared per-server uplink).

        ``slow`` / ``uplink`` are per-server fault multipliers and
        ``deadline`` caps the receive wait: under verify deadlines the
        batch assembles at min(slowest live arrival, deadline) — the
        verify server stops waiting and drops the late chunks (the engine
        masks their tokens; late rows' verify/send costs should already
        be zeroed out of ``S`` / ``num_emitted`` by the caller)."""
        r = self.receive_time(S, vocab, jitter, lanes=lanes, slow=slow,
                              uplink=uplink)
        if deadline is not None:
            r = jnp.minimum(r, deadline)
        v = self.verify_time(S)
        s = self.send_time(num_emitted)
        return r + v + s, (r, v, s)

    def overlapped_round_time(self, S: Array, prev_S: Array,
                              num_emitted: Array, vocab: int, jitter: Array,
                              lanes: int = 1, slow: Array = None,
                              uplink: Array = None, deadline: Array = None):
        """PEARL-style draft/verify overlap: round t's drafts (receive =
        draft + per-server uplink, unchanged shape) are produced WHILE the
        verify server is still scoring round t-1's chunk, so the steady-
        state round time is max(receive_t, verify_{t-1}) + send instead of
        their sum.  ``prev_S`` is the previous round's per-row allocation
        (the chunk in flight during this round's drafting); the per-server
        uplink sharing of ``receive_time`` is preserved verbatim, as is
        the deadline cap on the receive wait (see ``round_time``)."""
        r = self.receive_time(S, vocab, jitter, lanes=lanes, slow=slow,
                              uplink=uplink)
        if deadline is not None:
            r = jnp.minimum(r, deadline)
        v = self.verify_time(prev_S)
        s = self.send_time(num_emitted)
        return jnp.maximum(r, v) + s, (r, v, s)
