"""Utility functions U(x) for the GoodSpeed utility-maximization problem.

The paper (Eq. 1) maximizes ``U(x) = sum_i U_i(x_i)`` over the achievable
goodput region, with ``U_i`` continuously differentiable, strictly increasing
and strictly concave.  The experiments use the proportional-fairness utility
``U_i(x) = log x``.  We implement the standard alpha-fair family, which
contains log utility (alpha=1), throughput-optimal linear utility in the
limit alpha->0, and max-min fairness in the limit alpha->inf, plus optional
per-client weights.

All functions are pure jnp and safe under jit/grad.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray

# Numerical floor: gradients 1/x blow up at x=0 (the fluid analysis handles
# the boundary analytically via Lemma 2's boundary-drift argument; in the
# discrete implementation we clip, which corresponds to the bounded-gradient
# variant of Stolyar's algorithm).
_X_FLOOR = 1e-6


@dataclasses.dataclass(frozen=True)
class UtilitySpec:
    """alpha-fair utility family with per-client weights.

    alpha=1.0  -> U_i(x) = w_i log(x)          (proportional fairness; paper)
    alpha=0.0  -> U_i(x) = w_i x               (throughput maximization)
    otherwise  -> U_i(x) = w_i x^(1-alpha)/(1-alpha)
    """

    alpha: float = 1.0
    weights: tuple | None = None  # static per-client weights, broadcastable

    def _w(self, x: Array) -> Array:
        if self.weights is None:
            return jnp.ones_like(x)
        return jnp.asarray(self.weights, dtype=x.dtype)

    def value(self, x: Array) -> Array:
        """Total utility U(x) = sum_i U_i(x_i)."""
        xc = jnp.maximum(x, _X_FLOOR)
        w = self._w(xc)
        if self.alpha == 1.0:
            u = jnp.log(xc)
        elif self.alpha == 0.0:
            u = xc
        else:
            u = xc ** (1.0 - self.alpha) / (1.0 - self.alpha)
        return jnp.sum(w * u)

    def grad(self, x: Array) -> Array:
        """Per-component gradient dU_i/dx_i (the scheduler weights)."""
        xc = jnp.maximum(x, _X_FLOOR)
        w = self._w(xc)
        if self.alpha == 1.0:
            return w / xc
        if self.alpha == 0.0:
            return w
        return w * xc ** (-self.alpha)


LOG_UTILITY = UtilitySpec(alpha=1.0)
LINEAR_UTILITY = UtilitySpec(alpha=0.0)


def make_utility(name: str, weights=None) -> UtilitySpec:
    name = name.lower()
    if name in ("log", "proportional", "pf"):
        return UtilitySpec(alpha=1.0, weights=weights)
    if name in ("linear", "throughput"):
        return UtilitySpec(alpha=0.0, weights=weights)
    if name.startswith("alpha:"):
        return UtilitySpec(alpha=float(name.split(":", 1)[1]), weights=weights)
    raise ValueError(f"unknown utility {name!r}")
