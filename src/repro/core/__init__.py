"""GoodSpeed core: the paper's contribution as composable JAX modules."""
from repro.core.budget import TpuSpec, V5E, derive_budget, ridge_tokens
from repro.core.coordinator import Coordinator, RoundLog, RoundState, simulate
from repro.core.estimator import EstimatorState, GoodputEstimator, StepSchedule
from repro.core.fluid import integrate_fluid, optimal_goodput
from repro.core.goodput import expected_goodput, marginal_gain
from repro.core.latency import LatencyModel
from repro.core.scheduler import (SchedulerOutput, fixed_s, make_scheduler,
                                  objective_value, random_s, solve_greedy,
                                  solve_threshold)
from repro.core.speculative import (VerifyResult, acceptance_probability,
                                    draft_tokens_from_logits, verify)
from repro.core.utility import LOG_UTILITY, UtilitySpec, make_utility

__all__ = [
    "TpuSpec", "V5E", "derive_budget", "ridge_tokens",
    "Coordinator", "RoundLog", "RoundState", "simulate",
    "EstimatorState", "GoodputEstimator", "StepSchedule",
    "integrate_fluid", "optimal_goodput",
    "expected_goodput", "marginal_gain",
    "LatencyModel",
    "SchedulerOutput", "fixed_s", "make_scheduler", "objective_value",
    "random_s", "solve_greedy", "solve_threshold",
    "VerifyResult", "acceptance_probability", "draft_tokens_from_logits",
    "verify",
    "LOG_UTILITY", "UtilitySpec", "make_utility",
]
