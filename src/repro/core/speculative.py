"""Lossless speculative-decoding verification math (paper §II-A2).

Implements the Leviathan et al. (2023) rejection-sampling verification used
by GoodSpeed's verification server, batched over draft servers with *ragged*
draft lengths (each server i proposes S_i <= S_max tokens; rows are padded
to S_max and masked).

Given draft tokens s_1..s_S sampled from q_j(.), and the target model's
distributions p_j(.) computed in one parallel forward pass:

  accept s_j  iff  u_j <= min(1, p_j(s_j) / q_j(s_j)),  u_j ~ U(0,1)
  m = index of first rejection (= S if none)
  emit s_1..s_m plus ONE extra token:
      m < S: sampled from the residual  norm(max(0, p_{m+1} - q_{m+1}))
      m = S: sampled from p_{S+1}  (the "bonus" distribution)

This is distribution-lossless: the emitted sequence is an exact sample from
the target model (tested statistically in tests/test_speculative.py).

Indexing convention: ``p_logits`` has S_max+1 rows — row j in [0, S) is the
target distribution for draft position j and row S_i is the bonus
distribution for server i; the extra token is always drawn from row ``m``
(residual when m < S_i, plain target when m = S_i).

A fused Pallas TPU kernel with identical semantics lives in
``repro.kernels.spec_verify`` (this module is its jnp oracle and the
CPU/interpret fallback).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class VerifyResult(NamedTuple):
    accepted: Array          # i32[N] m_i: number of accepted draft tokens
    emitted: Array           # i32[N, S_max+1] accepted tokens + extra, -1 padded
    num_emitted: Array       # i32[N] m_i + 1  (realized goodput x_i(t))
    extra_token: Array       # i32[N] the correction/bonus token
    accept_ratio_sum: Array  # f32[N] sum_j min(1, p/q) over j < S_i (Eq. 3 input)


def _log_softmax(logits: Array) -> Array:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def verify(
    key: Array,
    draft_tokens: Array,   # i32[N, S_max]
    q_logits: Array,       # f32[N, S_max, V]     draft distributions
    p_logits: Array,       # f32[N, S_max+1, V]   target distributions
    lengths: Array,        # i32[N]               S_i <= S_max
    backend: str = "jnp",  # jnp | kernel (fused spec_verify gather)
    greedy: bool = False,  # deterministic exact-match verification
) -> VerifyResult:
    """Batched ragged rejection-sampling verification.

    ``backend="kernel"`` computes the per-token log p_j(s_j) / log q_j(s_j)
    through the fused ``repro.kernels.spec_verify`` gather-logprobs kernel
    (online logsumexp over vocab tiles; no [N, S, V] softmax
    materialization); the residual/bonus distributions then normalize
    only the single gathered row m per server.  ``"jnp"`` is the
    full-materialization oracle path.

    ``greedy=True`` is DETERMINISTIC greedy speculative decoding: a draft
    token is accepted iff it equals the target's argmax at its position,
    and the extra token is the target argmax at position m (no key
    consumed).  The emitted sequence is exactly the target model's greedy
    decode, so it depends only on the committed context — never on the
    batch row, the round boundaries, or rng — which is what makes request
    migration byte-equivalent to an uninterrupted run
    (tests/test_faults.py).  ``accept_ratio_sum`` becomes the match count
    (the empirical acceptance rate Eq. 3 folds is then the match rate)."""
    n, s_max = draft_tokens.shape
    v = q_logits.shape[-1]

    pos = jnp.arange(s_max)[None, :]                   # [1, S]
    in_draft = pos < lengths[:, None]                  # [N, S]

    tok = jnp.clip(draft_tokens, 0, v - 1)
    if greedy:
        p_top = jnp.argmax(p_logits[:, :s_max, :], axis=-1)
        accept = in_draft & (tok == p_top)
        ratio = accept.astype(jnp.float32)
        rejected = ~accept
        any_rej = jnp.any(rejected, axis=-1)
        first_rej = jnp.argmax(rejected, axis=-1)
        m = jnp.where(any_rej, first_rej, s_max).astype(jnp.int32)
        extra = jnp.argmax(jnp.take_along_axis(
            p_logits, m[:, None, None], axis=1)[:, 0, :],
            axis=-1).astype(jnp.int32)
        return _assemble(draft_tokens, in_draft, ratio, m, extra, n, s_max)
    if backend == "kernel":
        from repro.kernels.spec_verify import gather_logprobs
        logp_tok, _ = gather_logprobs(p_logits[:, :s_max, :], tok,
                                      impl="auto")
        logq_tok, _ = gather_logprobs(q_logits, tok, impl="auto")
    else:
        logq = _log_softmax(q_logits)                  # [N, S, V]
        logp_all = _log_softmax(p_logits)              # [N, S+1, V]
        logp = logp_all[:, :s_max, :]                  # rows for draft positions
        gather = lambda lg: jnp.take_along_axis(
            lg, tok[..., None], axis=-1)[..., 0]
        logp_tok = gather(logp)                        # [N, S]
        logq_tok = gather(logq)
    ratio = jnp.exp(jnp.minimum(logp_tok - logq_tok, 0.0))  # min(1, p/q)

    key_u, key_x = jax.random.split(key)
    u = jax.random.uniform(key_u, (n, s_max), jnp.float32)
    # Outside the drafted range force a rejection so m <= S_i.
    accept = jnp.where(in_draft, u <= ratio, False)

    rejected = ~accept
    any_rej = jnp.any(rejected, axis=-1)
    first_rej = jnp.argmax(rejected, axis=-1)
    m = jnp.where(any_rej, first_rej, s_max).astype(jnp.int32)  # == S_i if all pass

    # --- extra token: residual (m < S_i) or bonus (m == S_i) --------------
    if backend == "kernel":
        # gather the TWO raw logit rows (target at m, draft at min(m, S-1))
        # and log-softmax them row-locally — identical to indexing a full
        # log-softmax, without ever building one
        rows = _log_softmax(jnp.take_along_axis(
            p_logits, m[:, None, None], axis=1)[:, 0, :])
        q_rows = _log_softmax(jnp.take_along_axis(
            q_logits, jnp.minimum(m, s_max - 1)[:, None, None],
            axis=1)[:, 0, :])
    else:
        rows = jnp.take_along_axis(
            logp_all, m[:, None, None], axis=1)[:, 0, :]  # [N, V] target at m
        q_rows = jnp.take_along_axis(
            logq, jnp.minimum(m, s_max - 1)[:, None, None], axis=1)[:, 0, :]
    p_row = jnp.exp(rows)
    q_row = jnp.exp(q_rows)
    residual = jnp.maximum(p_row - q_row, 0.0)
    res_sum = jnp.sum(residual, axis=-1, keepdims=True)
    # If the residual is (numerically) empty, fall back to the target row —
    # this only happens when p == q where any sample is exact anyway.
    res_dist = jnp.where(res_sum > 1e-20, residual / jnp.maximum(res_sum, 1e-20),
                         jnp.exp(rows))
    is_bonus = m >= lengths                            # all drafts accepted
    extra_probs = jnp.where(is_bonus[:, None], jnp.exp(rows), res_dist)
    extra_logits = jnp.log(jnp.maximum(extra_probs, 1e-30))
    extra = jax.random.categorical(key_x, extra_logits, axis=-1).astype(jnp.int32)

    return _assemble(draft_tokens, in_draft, ratio, m, extra, n, s_max)


def _assemble(draft_tokens: Array, in_draft: Array, ratio: Array, m: Array,
              extra: Array, n: int, s_max: int) -> VerifyResult:
    """Shared output assembly: accepted prefix + extra token, -1 padded."""
    out_pos = jnp.arange(s_max + 1)[None, :]
    keep = out_pos < m[:, None]
    padded_draft = jnp.concatenate(
        [draft_tokens, jnp.full((n, 1), -1, draft_tokens.dtype)], axis=-1)
    emitted = jnp.where(keep, padded_draft, -1)
    emitted = jnp.where(out_pos == m[:, None], extra[:, None], emitted)

    ratio_sum = jnp.sum(jnp.where(in_draft, ratio, 0.0), axis=-1)
    return VerifyResult(
        accepted=m,
        emitted=emitted.astype(jnp.int32),
        num_emitted=(m + 1).astype(jnp.int32),
        extra_token=extra,
        accept_ratio_sum=ratio_sum,
    )


def draft_tokens_from_logits(key: Array, logits: Array) -> Array:
    """Ancestral sampling helper for draft servers: logits [.., V] -> tokens."""
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def acceptance_probability(p_logits: Array, q_logits: Array) -> Array:
    """Analytic per-position acceptance rate  alpha = E_{s~q} min(1, p/q)
    = sum_s min(p(s), q(s)) = 1 - TV(p, q).  Used for tests and for
    synthetic workload generation with controlled alpha."""
    p = jax.nn.softmax(p_logits.astype(jnp.float32), axis=-1)
    q = jax.nn.softmax(q_logits.astype(jnp.float32), axis=-1)
    return jnp.sum(jnp.minimum(p, q), axis=-1)
