"""Expected-goodput model for speculative decoding (paper §III-B).

For a draft of length S verified by rejection sampling with per-token
acceptance probability alpha, the number of accepted tokens is a geometric
random variable truncated at S, and the verifier always emits one extra
token (either the residual-resampled correction or, when all S drafts are
accepted, a bonus token from p_{S+1}).  The expected number of tokens
emitted per round is therefore (Leviathan et al. 2023, Eq. used by the
paper):

    mu(S; alpha) = (1 - alpha^(S+1)) / (1 - alpha)
                 = 1 + alpha + alpha^2 + ... + alpha^S.

The *marginal* value of extending a draft from length S to S+1 is
alpha^(S+1); it is positive and strictly decreasing in S, which makes the
GOODSPEED-SCHED objective separable-concave over the integer simplex and
exactly solvable by greedy marginal allocation (see scheduler.py).
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

# Acceptance rates are probabilities in (0,1); the paper assumes
# alpha_max < 1 (Assumption 2).  We clip for numerical safety: at alpha=1
# mu(S)=S+1 via the limit, handled by jnp.where below.
_EPS = 1e-7


def expected_goodput(S: Array, alpha: Array) -> Array:
    """mu(S; alpha) = (1 - alpha^(S+1)) / (1 - alpha), elementwise.

    Handles the alpha -> 1 limit (mu = S+1) and alpha -> 0 (mu = 1).
    ``S`` may be float (fluid relaxation) or integer (actual allocations).
    """
    a = jnp.clip(alpha, 0.0, 1.0)
    s = jnp.asarray(S, dtype=jnp.result_type(float, a.dtype))
    near_one = a > 1.0 - _EPS
    a_safe = jnp.where(near_one, 0.5, a)
    mu = (1.0 - a_safe ** (s + 1.0)) / (1.0 - a_safe)
    return jnp.where(near_one, s + 1.0, mu)


def marginal_gain(S: Array, alpha: Array) -> Array:
    """mu(S+1) - mu(S) = alpha^(S+1): value of the (S+1)-th draft slot."""
    a = jnp.clip(alpha, 0.0, 1.0)
    s = jnp.asarray(S, dtype=jnp.result_type(float, a.dtype))
    return a ** (s + 1.0)


def inverse_marginal(theta: Array, alpha: Array) -> Array:
    """Largest integer S >= 0 such that marginal_gain(S-1) >= theta, i.e.
    the number of slots client i claims at price theta:

        S_i(theta) = max{ s in Z+ : alpha^s >= theta } = floor(log theta / log alpha)

    (0 when even the first slot's marginal alpha^1 ... note: slot s has
    marginal alpha^s for s = 1..S counted after the free correction token;
    we define slot s's marginal as alpha^s so S_i(theta) counts s with
    alpha^s >= theta).  Used by the bisection solver.
    """
    a = jnp.clip(alpha, _EPS, 1.0 - _EPS)
    t = jnp.clip(theta, _EPS, 1.0)
    # alpha^s >= theta  <=>  s <= log(theta)/log(alpha)   (log alpha < 0)
    smax = jnp.floor(jnp.log(t) / jnp.log(a))
    return jnp.maximum(smax, 0.0)


def simulate_accepts(key, S: int, alpha: float, shape=()) -> Array:
    """Sample the number of emitted tokens for a length-S draft: truncated
    geometric + 1 correction/bonus.  Used by simulators and tests."""
    import jax

    u = jax.random.uniform(key, shape + (S,))
    rejected = u >= alpha  # True where draft token j is rejected
    # index of first rejection, or S if none
    any_rej = jnp.any(rejected, axis=-1)
    first_rej = jnp.argmax(rejected, axis=-1)
    m = jnp.where(any_rej, first_rej, S)
    return m + 1  # +1 correction (m<S) or bonus (m==S) token
