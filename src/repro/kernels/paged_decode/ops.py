"""Cache-aware dispatcher for block-table-native paged decode attention.

``paged_flash_decode(q, cache, q_pos)`` attends over a ``PagedAttnCache``
without ever materializing the gathered logical view:

* ``impl="kernel"`` — the Pallas kernel (scalar-prefetched block table;
  compiled on TPU, ``interpret=True`` elsewhere);
* ``impl="ref"`` — the fused jnp fallback (dynamic loop over allocated
  blocks) so CPU runs see the same no-gather win;
* ``impl="auto"`` (default) — kernel on TPU, ref otherwise.

``PagedMLACache`` is rejected: MLA decode runs the absorbed latent-space
path (``attention.mla_attend``), which never materializes per-head K/V in
the first place — the model-level ``attn_backend`` dispatch keeps MLA on
the jnp path instead of calling this op.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_decode.kernel import paged_flash_decode_kernel
from repro.kernels.paged_decode.ref import paged_flash_decode_ref
from repro.serving.kv_cache import PagedAttnCache

Array = jnp.ndarray


def paged_flash_decode(q: Array, cache: PagedAttnCache, q_pos: Array, *,
                       softcap: float = 0.0, impl: str = "auto",
                       interpret: Optional[bool] = None) -> Array:
    """q: [B, Sq, H, hd] or [B, H, hd]; q_pos: i32[B, Sq] or i32[B].
    Returns attention output of q's shape (q.dtype under "ref", f32 under
    "kernel", matching the package's existing kernels)."""
    if not isinstance(cache, PagedAttnCache):
        raise TypeError(
            f"paged_flash_decode needs a PagedAttnCache, got "
            f"{type(cache).__name__} (MLA caches stay on the absorbed "
            f"jnp path)")
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "kernel":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return paged_flash_decode_kernel(
            q, cache.kpool, cache.vpool, cache.table, cache.pos_arr, q_pos,
            softcap=softcap, interpret=interpret)
    if impl == "ref":
        return paged_flash_decode_ref(
            q, cache.kpool, cache.vpool, cache.table, cache.pos_arr, q_pos,
            softcap=softcap)
    raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
