from repro.kernels.paged_decode.ops import paged_flash_decode
from repro.kernels.paged_decode.ref import paged_flash_decode_ref

__all__ = ["paged_flash_decode", "paged_flash_decode_ref"]
