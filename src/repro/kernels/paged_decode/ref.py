"""Fused jnp fallback for the block-table-native paged decode kernel.

Unlike ``attention.paged_dot_attention`` — which first gathers the full
``[B, M*bs, ...]`` logical view through the block table and then runs the
dense core — this reference indexes the pool one logical block per loop
step and folds it into an online-softmax accumulator.  Two consequences:

* no materialized contiguous copy of the cache (the per-step gather is
  one ``[B, bs, KV, hd]`` block, freed before the next step);
* the loop bound is the highest ALLOCATED block count, not the table
  width: allocated logical blocks form a per-row prefix (free-list
  invariant 3, docs/KV_CACHE.md), so per-token decode cost tracks pool
  *occupancy* while the gather path pays for full logical *capacity*.

This is the CPU/interpret backend behind ``ops.paged_flash_decode`` —
the microbench (``benchmarks/paged_decode_bench.py``) measures exactly
this occupancy-vs-capacity gap.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG = -1e30


def paged_flash_decode_ref(q: Array, kpool: Array, vpool: Array,
                           table: Array, pos_arr: Array, q_pos: Array, *,
                           softcap: float = 0.0) -> Array:
    """q: [B, Sq, H, hd] (or [B, H, hd]); kpool/vpool: [P, bs, KV, hd];
    table: i32[B, M]; pos_arr: i32[B, M*bs]; q_pos: i32[B, Sq] (or i32[B]).
    Returns q.dtype of q's shape."""
    single = q.ndim == 3
    if single:
        q, q_pos = q[:, None], q_pos[:, None]
    b, sq, h, hd = q.shape
    bs, kv = kpool.shape[1], kpool.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).reshape(b, sq, kv, g, hd)

    # allocated logical blocks are a per-row prefix -> the max allocated
    # count bounds a dynamic-trip-count loop (lowered to while_loop):
    # decode cost follows occupancy, not table width
    n_live = jnp.max(jnp.sum((table >= 0).astype(jnp.int32), axis=1))

    def body(mi, carry):
        m_run, l_run, acc = carry
        phys = jax.lax.dynamic_index_in_dim(table, mi, axis=1,
                                            keepdims=False)      # [B]
        ks = kpool[jnp.maximum(phys, 0)]          # [B, bs, KV, hd]
        vs = vpool[jnp.maximum(phys, 0)]
        kvp = jax.lax.dynamic_slice_in_dim(pos_arr, mi * bs, bs,
                                           axis=1)               # [B, bs]
        s = jnp.einsum("bqkgh,blkh->bqkgl", qf, ks,
                       preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = (kvp >= 0)[:, None, :] & (phys >= 0)[:, None, None] \
            & (kvp[:, None, :] <= q_pos[:, :, None])             # [B, Sq, bs]
        maskb = mask[:, :, None, None, :]
        s = jnp.where(maskb, s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(maskb, p, 0.0)              # fully-masked rows -> 0
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgl,blkh->bqkgh", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr[..., None] + pv

    m0 = jnp.full((b, sq, kv, g), NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = out.reshape(b, sq, h, hd).astype(q.dtype)
    return out[:, 0] if single else out
