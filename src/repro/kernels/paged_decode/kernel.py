"""Pallas TPU kernel: block-table-native paged GQA decode attention.

The kernel consumes the ``PagedAttnCache`` storage DIRECTLY — shared block
pools ``[P, bs, KV, hd]``, per-row block table ``i32[B, M]`` and per-slot
positions — instead of first gathering the contiguous ``[B, M*bs, ...]``
logical view (``kv_cache.paged_view``).  Per decode step that removes the
O(B * M*bs * KV * hd) gather traffic per layer; the pool blocks stream
HBM->VMEM exactly once each.

Grid: (B, KV_heads, M logical blocks), block axis innermost.  The block
table is a SCALAR-PREFETCH operand (``pltpu.PrefetchScalarGridSpec``): the
K/V BlockSpec index maps read ``table[b, m]`` to DMA the row's m-th
logical block straight out of the pool.  Unallocated table entries (-1)
clamp to pool block 0; their slots carry ``pos_arr == -1`` (the write-path
invariant "no valid slot without a backing block", docs/KV_CACHE.md) so
the mask discards them, and an ``@pl.when`` guard skips the FLOPs of
fully-dead blocks (the DMA itself still runs under the automatic
pipeliner — acceptable because dead blocks are the table *suffix*).

Queries may be a chunk (speculative verify: [B, Sq, H, hd]): the Sq and
group axes fold into one ``Sq*G`` row axis so scores stay a single 2-D
MXU matmul per block; per-row query positions handle intra-chunk
causality (the whole chunk is written to the cache before attention
runs, exactly like the jnp path).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(tbl_ref, qp_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_s, l_s, acc_s, *, n_blocks, scale, softcap):
    b = pl.program_id(0)
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    phys = tbl_ref[b, mi]                            # i32: -1 = unallocated
    kv_pos = pos_ref[0]                              # [bs] i32, -1 = empty
    q_pos = qp_ref[0]                                # [Sq*G] i32
    slot_ok = (phys >= 0) & (kv_pos >= 0)

    @pl.when(jnp.any(slot_ok))                       # skip dead blocks
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [Sq*G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)       # [bs, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)       # [bs, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [Sq*G, bs]
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = slot_ok[None, :] & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG)
        m_prev = m_s[...]                            # [Sq*G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        # explicit zero: a fully-masked query row has s == m_new == NEG
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_s[...] = m_new

    @pl.when(mi == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_flash_decode_kernel(q, kpool, vpool, table, pos_arr, q_pos, *,
                              softcap: float = 0.0, interpret: bool = True):
    """q: [B, Sq, H, hd] (or [B, H, hd]); kpool/vpool: [P, bs, KV, hd];
    table: i32[B, M] (-1 = unallocated); pos_arr: i32[B, M*bs] (-1 = empty);
    q_pos: i32[B, Sq] (or i32[B]).  Returns f32 of q's shape."""
    single = q.ndim == 3
    if single:
        q, q_pos = q[:, None], q_pos[:, None]
    b, sq, h, hd = q.shape
    bs, kv = kpool.shape[1], kpool.shape[2]
    m_blocks = table.shape[1]
    g = h // kv
    sqg = sq * g

    # fold (Sq, G) into one row axis; q_pos repeats g-fold to match
    qr = q.reshape(b, sq, kv, g, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, kv, sqg, hd)
    qp = jnp.repeat(q_pos.astype(jnp.int32), g, axis=1)        # [B, Sq*G]

    kernel = functools.partial(_kernel, n_blocks=m_blocks,
                               scale=1.0 / math.sqrt(hd), softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                                 # table
        grid=(b, kv, m_blocks),
        in_specs=[
            pl.BlockSpec((1, sqg), lambda i, j, t, tbl: (i, 0),
                         memory_space=pltpu.SMEM),             # q_pos
            pl.BlockSpec((1, 1, sqg, hd), lambda i, j, t, tbl: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda i, j, t, tbl: (jnp.maximum(tbl[i, t], 0),
                                               0, j, 0)),      # k block
            pl.BlockSpec((1, bs, 1, hd),
                         lambda i, j, t, tbl: (jnp.maximum(tbl[i, t], 0),
                                               0, j, 0)),      # v block
            pl.BlockSpec((1, bs), lambda i, j, t, tbl: (i, t)),  # pos_arr
        ],
        out_specs=pl.BlockSpec((1, 1, sqg, hd),
                               lambda i, j, t, tbl: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sqg, 1), jnp.float32),
            pltpu.VMEM((sqg, 1), jnp.float32),
            pltpu.VMEM((sqg, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, sqg, hd), jnp.float32),
        interpret=interpret,
    )(table, qp, qr, kpool, vpool, pos_arr)
    out = out.reshape(b, kv, sq, g, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, sq, h, hd)
    return out[:, 0] if single else out
