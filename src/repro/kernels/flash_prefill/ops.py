"""jit'd wrapper for the flash-prefill kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_kernel

Array = jnp.ndarray


def flash_prefill(q: Array, k: Array, v: Array, *, window: int = 0,
                  q_tile: int = 256, kv_tile: int = 256,
                  interpret: bool = True) -> Array:
    """Causal (optionally sliding-window) chunk self-attention.

    q: [B, S, H, hd]; k/v: [B, S, KV, hd] (GQA: KV divides H)."""
    return flash_prefill_kernel(q, k, v, window=window, q_tile=q_tile,
                                kv_tile=kv_tile, interpret=interpret)
