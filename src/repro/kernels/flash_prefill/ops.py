"""jit'd wrapper for the flash-prefill kernel.

``impl`` mirrors the decode packages: ``"kernel"`` (default) runs the
Pallas kernel (interpreted off-TPU), ``"ref"`` the jnp oracle, ``"auto"``
picks kernel on TPU and ref otherwise — the model-level
``attn_backend="kernel"`` prefill dispatch uses "auto" so CPU admission
prefills stay vectorized jnp instead of interpreted Pallas.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_kernel
from repro.kernels.flash_prefill.ref import flash_prefill_ref

Array = jnp.ndarray


def flash_prefill(q: Array, k: Array, v: Array, *, window: int = 0,
                  q_tile: int = 256, kv_tile: int = 256,
                  impl: str = "kernel",
                  interpret: Optional[bool] = None) -> Array:
    """Causal (optionally sliding-window) chunk self-attention.

    q: [B, S, H, hd]; k/v: [B, S, KV, hd] (GQA: KV divides H)."""
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return flash_prefill_ref(q, k, v, window=window)
    if impl != "kernel":
        raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_prefill_kernel(q, k, v, window=window, q_tile=q_tile,
                                kv_tile=kv_tile, interpret=interpret)
