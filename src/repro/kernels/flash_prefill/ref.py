"""jnp oracle for the causal (optionally windowed) flash-prefill kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def flash_prefill_ref(q: Array, k: Array, v: Array, window: int = 0) -> Array:
    """Causal self-attention over one chunk.

    q: [B, S, H, hd]; k/v: [B, S, KV, hd].  Positions are 0..S-1.
    Returns [B, S, H, hd] f32.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, s, kv, g, hd) / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,blkh->bqkgl", qf, k.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgl,blkh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)
