"""Pallas TPU kernel: causal flash attention for prefill chunks.

Grid: (B, KV_heads, num_Q_tiles, num_KV_tiles) with the KV-tile axis
innermost: each (b, h, i) row streams KV tiles j = 0..i through VMEM,
maintaining the online-softmax (m, l, acc) in VMEM scratch and writing the
normalized [QT, G·hd] output block on the last contributing tile.

Causality is exploited two ways:
  * tiles with j > i are masked entirely (the kernel writes on tile j == i,
    so the dead tiles only cost the masked branch — on real TPU one would
    skip them with a grid mapping; kept simple here);
  * sliding-window masks drop tiles with i·QT - (j+1)·KT >= window.

VMEM: QT x hd q tile + KT x hd k/v tiles + QT x KT scores — QT=KT=256,
hd<=256 stays well under v5e's ~16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            n_kv_tiles, qt, kt, scale, window, g):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    @pl.when(j <= i)  # causal: later KV tiles can't contribute
    def _body():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale   # [QT, G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [KT, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)           # [KT, hd]
        qt_, g_, hd = q.shape
        s = jax.lax.dot_general(q.reshape(qt_ * g_, hd), k,
                                (((1,), (1,)), ((), ())))  # [QT*G, KT]
        s = s.reshape(qt_, g_, kt)
        q_pos = i * qt + jax.lax.broadcasted_iota(jnp.int32, (qt_, g_, kt), 0)
        k_pos = j * kt + jax.lax.broadcasted_iota(jnp.int32, (qt_, g_, kt), 2)
        mask = k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG)

        m_prev = m_s[...]                                 # [QT, G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.reshape(qt_ * g_, kt), v, (((1,), (0,)), ((), ())))
        acc_s[...] = acc_s[...] * corr + pv.reshape(qt_, g_, hd)
        m_s[...] = m_new

    @pl.when(j == i)  # last contributing tile for this q tile
    def _finalize():
        o_ref[0, :, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "q_tile", "kv_tile",
                                    "interpret"))
def flash_prefill_kernel(q, k, v, *, window: int = 0, q_tile: int = 256,
                         kv_tile: int = 256, interpret: bool = True):
    """q: [B, S, H, hd]; k/v: [B, S, KV, hd] -> [B, S, H, hd] f32."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qt = min(q_tile, s)
    kt = min(kv_tile, s)
    assert qt == kt, "finalize-at-diagonal requires square tiles"
    assert s % qt == 0 and s % kt == 0, (s, qt, kt)
    nq, nk = s // qt, s // kt

    qg = q.reshape(b, s, kv, g, hd)
    kernel = functools.partial(_kernel, n_kv_tiles=nk, qt=qt, kt=kt,
                               scale=1.0 / math.sqrt(hd), window=window, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qt, 1, g, hd), lambda bi, hi, i, j: (bi, i, hi, 0, 0)),
            pl.BlockSpec((1, kt, 1, hd), lambda bi, hi, i, j: (bi, j, hi, 0)),
            pl.BlockSpec((1, kt, 1, hd), lambda bi, hi, i, j: (bi, j, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, qt, 1, g, hd),
                               lambda bi, hi, i, j: (bi, i, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, kv, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((qt, g, 1), jnp.float32),
            pltpu.VMEM((qt, g, 1), jnp.float32),
            pltpu.VMEM((qt, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, s, h, hd)
