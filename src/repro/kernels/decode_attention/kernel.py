"""Pallas TPU kernel: GQA flash-decode — a short query chunk vs a KV cache,
streamed HBM->VMEM in L-tiles with an online-softmax accumulator.

Grid: (B, KV_heads, num_L_tiles).  Per step the kernel loads one
(LT, hd) K tile and V tile for one kv head, computes the scores for the
chunk's ``Sq*G`` query rows (the chunk and group-query axes fold into one
MXU row axis) on the VPU/MXU, applies the position/window mask from the
cache's pos_arr, and folds into running (m, l, acc) VMEM scratch.  The
final tile normalizes and writes the (Sq*G, hd) output block.

Masking is purely position-based — ``kv_pos >= 0`` (slot holds a token),
``kv_pos <= q_pos`` (causal), ``q_pos - kv_pos < window`` — exactly the
``dot_attention`` contract, so static left-aligned caches and wrapped
sliding-window ring buffers go through the same kernel.  Chunked decode
(the speculative verify path, Sq = s_max+1) works because the whole chunk
is written to the cache before attention runs: intra-chunk causality
falls out of the per-query positions.  Fully-masked query rows (idle
serving slots, pos_arr all -1) produce exact zeros, never a mean-of-v.

Tile choice: LT=512 rows x hd(<=256) lanes of K + V in bf16 = 512KiB —
comfortably inside v5e VMEM with double-buffering; hd is lane-aligned
(128/256) for every assigned arch except whisper (64, still aligned).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
DEFAULT_LT = 512


def _kernel(qp_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_s, l_s, acc_s, *, n_tiles, scale, window, softcap):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [Sq*G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [LT, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)           # [LT, hd]
    kv_pos = pos_ref[0]                              # [LT] i32
    q_pos = qp_ref[0]                                # [Sq*G] i32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [Sq*G, LT]
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kv_pos[None, :] >= 0) & (kv_pos[None, :] <= q_pos[:, None])
    if window > 0:
        valid &= (q_pos[:, None] - kv_pos[None, :]) < window
    s = jnp.where(valid, s, NEG)

    m_prev = m_s[...]                                # [Sq*G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [Sq*G, LT]
    # explicit zero for masked slots: a fully-masked query row has
    # s == m_new == NEG, where exp(0) = 1 would poison l (mean-of-v bug)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                   # [Sq*G, 1]
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))              # [Sq*G, hd]
    m_s[...] = m_new

    @pl.when(t == n_tiles - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "softcap", "tile", "interpret"))
def flash_decode_kernel(q, k, v, kv_pos, q_pos, *, window: int = 0,
                        softcap: float = 0.0, tile: int = DEFAULT_LT,
                        interpret: bool = True):
    """q: [B, Sq, H, hd] (or [B, H, hd]); k/v: [B, L, KV, hd];
    kv_pos: i32[B, L] (-1 = empty); q_pos: i32[B, Sq] (or i32[B]).
    Returns f32 of q's shape."""
    single = q.ndim == 3
    if single:
        q, q_pos = q[:, None], q_pos[:, None]
    b, sq, h, hd = q.shape
    _, l, kv, _ = k.shape
    g = h // kv
    sqg = sq * g
    tile = min(tile, l)
    if l % tile != 0:
        pad = tile - l % tile
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        l += pad
    n_tiles = l // tile

    # fold (Sq, G) into one MXU row axis; q_pos repeats g-fold to match
    qg = q.reshape(b, sq, kv, g, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, kv, sqg, hd)
    qp = jnp.repeat(q_pos.astype(jnp.int32), g, axis=1)        # [B, Sq*G]
    kernel = functools.partial(_kernel, n_tiles=n_tiles,
                               scale=1.0 / math.sqrt(hd), window=window,
                               softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_tiles),
        in_specs=[
            pl.BlockSpec((1, sqg), lambda i, j, t: (i, 0),
                         memory_space=pltpu.SMEM),             # q_pos
            pl.BlockSpec((1, 1, sqg, hd), lambda i, j, t: (i, j, 0, 0)),
            pl.BlockSpec((1, tile, 1, hd), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, tile, 1, hd), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, tile), lambda i, j, t: (i, t)),   # kv_pos
        ],
        out_specs=pl.BlockSpec((1, 1, sqg, hd), lambda i, j, t: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, sqg, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((sqg, 1), jnp.float32),
            pltpu.VMEM((sqg, 1), jnp.float32),
            pltpu.VMEM((sqg, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, qg, k, v, kv_pos)
    out = out.reshape(b, kv, sq, g, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, sq, h, hd)
    return out[:, 0] if single else out
