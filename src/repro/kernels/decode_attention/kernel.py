"""Pallas TPU kernel: GQA flash-decode — one query token vs a KV cache,
streamed HBM->VMEM in L-tiles with an online-softmax accumulator.

Grid: (B, KV_heads, num_L_tiles).  Per step the kernel loads one
(LT, hd) K tile and V tile for one kv head, computes the G group-query
scores on the VPU/MXU, applies the position/window mask from the cache's
pos_arr, and folds into running (m, l, acc) VMEM scratch.  The final tile
normalizes and writes the (G, hd) output block.

Tile choice: LT=512 rows x hd(<=256) lanes of K + V in bf16 = 512KiB —
comfortably inside v5e VMEM with double-buffering; hd is lane-aligned
(128/256) for every assigned arch except whisper (64, still aligned).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
DEFAULT_LT = 512


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_s, l_s, acc_s, *, n_tiles, scale, window):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [LT, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)           # [LT, hd]
    kv_pos = pos_ref[0]                              # [LT] i32
    q_pos = qpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, LT]
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window > 0:
        valid &= (q_pos - kv_pos) < window
    s = jnp.where(valid[None, :], s, NEG)

    m_prev = m_s[...]                                # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [G, LT]
    corr = jnp.exp(m_prev - m_new)                   # [G, 1]
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))              # [G, hd]
    m_s[...] = m_new

    @pl.when(t == n_tiles - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "tile", "interpret"))
def flash_decode_kernel(q, k, v, kv_pos, q_pos, *, window: int = 0,
                        tile: int = DEFAULT_LT, interpret: bool = True):
    """q: [B, H, hd]; k/v: [B, L, KV, hd]; kv_pos: i32[B, L] (-1 = empty);
    q_pos: i32[B].  Returns [B, H, hd] f32."""
    b, h, hd = q.shape
    _, l, kv, _ = k.shape
    g = h // kv
    tile = min(tile, l)
    if l % tile != 0:
        pad = tile - l % tile
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        l += pad
    n_tiles = l // tile

    qg = q.reshape(b, kv, g, hd)
    kernel = functools.partial(_kernel, n_tiles=n_tiles,
                               scale=1.0 / math.sqrt(hd), window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_tiles),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, t: (i,),
                         memory_space=pltpu.SMEM),             # q_pos
            pl.BlockSpec((1, 1, g, hd), lambda i, j, t: (i, j, 0, 0)),
            pl.BlockSpec((1, tile, 1, hd), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, tile, 1, hd), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, tile), lambda i, j, t: (i, t)),   # kv_pos
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j, t: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qg, k, v, kv_pos)
    return out.reshape(b, h, hd)
