"""Pure-jnp oracle for the GQA flash-decode kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def flash_decode_ref(q: Array, k: Array, v: Array, kv_pos: Array,
                     kv_valid: Array, q_pos: Array,
                     window: int = 0) -> Array:
    """Single-token GQA attention over a cache.

    q: [B, H, hd]; k/v: [B, L, KV, hd]; kv_pos: i32[B, L]; kv_valid: bool[B, L];
    q_pos: i32[B].  Returns [B, H, hd] (f32).
    """
    b, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, hd) / math.sqrt(hd)
    s = jnp.einsum("bkgh,blkh->bkgl", qf, k.astype(jnp.float32))
    mask = kv_valid & (kv_pos <= q_pos[:, None])
    if window > 0:
        mask &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgl,blkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd)
