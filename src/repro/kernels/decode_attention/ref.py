"""Pure-jnp oracles for the GQA flash-decode kernel.

``flash_decode_ref`` is the historical single-token softmax oracle the
kernel sweeps diff against.  ``flash_decode_chunk_ref`` is the chunked
CPU fallback used by ``ops.flash_decode(impl="ref")``: it mirrors
``models.attention.dot_attention``'s decode path (single KV block,
f32-accumulated einsums, explicit masked-zero probabilities, identical
operation order) so a serving engine switched between ``attn_backend``
values on CPU sees bit-identical logits.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG = -1e30


def flash_decode_ref(q: Array, k: Array, v: Array, kv_pos: Array,
                     kv_valid: Array, q_pos: Array,
                     window: int = 0, softcap: float = 0.0) -> Array:
    """Single-token GQA attention over a cache.

    q: [B, H, hd]; k/v: [B, L, KV, hd]; kv_pos: i32[B, L]; kv_valid: bool[B, L];
    q_pos: i32[B].  Returns [B, H, hd] (f32).
    """
    b, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, hd) / math.sqrt(hd)
    s = jnp.einsum("bkgh,blkh->bkgl", qf, k.astype(jnp.float32))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kv_valid & (kv_pos <= q_pos[:, None])
    if window > 0:
        mask &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = p * mask[:, None, None, :]           # fully-masked rows -> zeros
    out = jnp.einsum("bkgl,blkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd)


def flash_decode_chunk_ref(q: Array, k: Array, v: Array, kv_pos: Array,
                           kv_valid: Array, q_pos: Array,
                           window: int = 0, softcap: float = 0.0) -> Array:
    """Chunked decode fallback, operation-for-operation identical to
    ``dot_attention``'s single-block decode path.

    q: [B, Sq, H, hd]; k/v: [B, L, KV, hd]; q_pos: i32[B, Sq].
    Returns [B, Sq, H, hd] in q.dtype.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgh,blkh->bqkgl", qf, k,
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kv_valid[:, None, :] & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        mask &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    maskb = mask[:, :, None, None, :]
    s = jnp.where(maskb, s, NEG)
    m = jnp.maximum(jnp.full(s.shape[:-1], NEG, jnp.float32),
                    jnp.max(s, axis=-1))
    p = jnp.exp(s - m[..., None])
    p = jnp.where(maskb, p, 0.0)             # fully-masked rows -> zeros
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bqkgl,blkh->bqkgh", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    out = pv / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, vd).astype(q.dtype)
