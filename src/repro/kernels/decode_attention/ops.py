"""jit'd wrapper for the flash-decode kernel, cache-aware."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import flash_decode_kernel
from repro.serving.kv_cache import AttnCache

Array = jnp.ndarray


def flash_decode(q: Array, cache_or_k, v: Array | None = None,
                 kv_pos: Array | None = None, q_pos: Array | None = None,
                 *, window: int = 0, tile: int = 512,
                 interpret: bool = True) -> Array:
    """Either flash_decode(q, cache, q_pos=...) or explicit (q, k, v,
    kv_pos, q_pos)."""
    if isinstance(cache_or_k, AttnCache):
        cache = cache_or_k
        return flash_decode_kernel(q, cache.k, cache.v, cache.pos_arr,
                                   q_pos, window=window, tile=tile,
                                   interpret=interpret)
    return flash_decode_kernel(q, cache_or_k, v, kv_pos, q_pos,
                               window=window, tile=tile, interpret=interpret)
