"""Cache-aware jit'd wrapper for the GQA flash-decode kernel.

Accepts any ``AttnCache`` — static left-aligned caches AND sliding-window
ring buffers: masking is computed from the cache's absolute ``pos_arr``
exactly like ``dot_attention`` (validity ``pos >= 0``, causality, window),
so a wrapped ring layout needs no special casing.  Queries may be a
single token ([B, H, hd]) or a decode chunk ([B, Sq, H, hd], the
speculative verify path).  MLA caches are rejected — MLA decode runs the
absorbed latent-space path in ``models.attention``.

``impl`` selects the execution path:
* ``"kernel"`` (default) — the Pallas kernel, interpreted off-TPU;
* ``"ref"`` — the chunked jnp fallback that mirrors ``dot_attention``'s
  decode math exactly (CPU serving path);
* ``"auto"`` — kernel on TPU, ref otherwise (what the model-level
  ``attn_backend="kernel"`` dispatch uses).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import flash_decode_kernel
from repro.kernels.decode_attention.ref import flash_decode_chunk_ref
from repro.serving.kv_cache import AttnCache

Array = jnp.ndarray


def flash_decode(q: Array, cache_or_k, v: Array | None = None,
                 kv_pos: Array | None = None, q_pos: Array | None = None,
                 *, window: int = 0, softcap: float = 0.0, tile: int = 512,
                 impl: str = "kernel",
                 interpret: Optional[bool] = None) -> Array:
    """Either flash_decode(q, cache, q_pos=...) or explicit (q, k, v,
    kv_pos, q_pos)."""
    if isinstance(cache_or_k, AttnCache):
        cache = cache_or_k
        k, v, kv_pos = cache.k, cache.v, cache.pos_arr
    elif hasattr(cache_or_k, "pos_arr"):
        raise TypeError(
            f"flash_decode handles AttnCache (static or ring), got "
            f"{type(cache_or_k).__name__}; MLA/paged caches have their own "
            f"paths (mla_attend / paged_flash_decode)")
    else:
        k = cache_or_k
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        single = q.ndim == 3
        qc = q[:, None] if single else q
        qp = q_pos[:, None] if single else q_pos
        out = flash_decode_chunk_ref(qc, k, v, kv_pos, kv_pos >= 0, qp,
                                     window=window, softcap=softcap)
        return out[:, 0] if single else out
    if impl != "kernel":
        raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_decode_kernel(q, k, v, kv_pos, q_pos, window=window,
                               softcap=softcap, tile=tile,
                               interpret=interpret)
