from repro.kernels.decode_attention.ops import flash_decode
from repro.kernels.decode_attention.ref import flash_decode_ref

__all__ = ["flash_decode", "flash_decode_ref"]
