from repro.kernels.spec_verify.ops import gather_logprobs
from repro.kernels.spec_verify.ref import gather_logprobs_ref

__all__ = ["gather_logprobs", "gather_logprobs_ref"]
