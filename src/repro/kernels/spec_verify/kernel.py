"""Pallas TPU kernel: fused online-logsumexp + token gather over vocab tiles.

Grid: (rows, num_vocab_tiles).  Each step loads one (1, VT) logit tile into
VMEM, updates the running (max, sumexp) in SMEM scratch, and accumulates the
gathered logit for the row's token if it falls inside this tile.  The last
tile writes  logprob = gathered - (m + log l)  and  logz = m + log l.

VMEM budget: one VT-wide f32 tile (+bf16 input tile) — VT=2048 keeps the
working set < 16 KiB, far under the ~16 MiB v5e VMEM, so multiple rows can
be pipelined by the compiler; VT is a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE = 2048
NEG = -1e30


def _kernel(tok_ref, logits_ref, lp_ref, lz_ref, m_s, l_s, g_s, *, n_tiles,
            tile):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[0] = NEG
        l_s[0] = 0.0
        g_s[0] = NEG

    x = logits_ref[0, :].astype(jnp.float32)            # [VT]
    tile_max = jnp.max(x)
    m_prev = m_s[0]
    m_new = jnp.maximum(m_prev, tile_max)
    l_s[0] = l_s[0] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(x - m_new))
    m_s[0] = m_new

    # gather: token index relative to this tile
    t = tok_ref[0] - j * tile
    in_tile = (t >= 0) & (t < tile)
    idx = jnp.clip(t, 0, tile - 1)
    val = jnp.where(in_tile, x[idx], NEG)
    g_s[0] = jnp.maximum(g_s[0], val)   # exactly one tile contributes

    @pl.when(j == n_tiles - 1)
    def _finalize():
        logz = m_s[0] + jnp.log(jnp.maximum(l_s[0], 1e-30))
        lz_ref[0] = logz
        lp_ref[0] = g_s[0] - logz


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def gather_logprobs_kernel(logits, tokens, *, tile: int = DEFAULT_TILE,
                           interpret: bool = True):
    """logits: [R, V]; tokens: i32[R] -> (logprob f32[R], logz f32[R])."""
    r, v = logits.shape
    tile = min(tile, v)
    if v % tile != 0:  # pad vocab to a tile multiple with -inf
        pad = tile - v % tile
        logits = jnp.pad(logits, ((0, 0), (0, pad)), constant_values=NEG)
        v = v + pad
    n_tiles = v // tile

    kernel = functools.partial(_kernel, n_tiles=n_tiles, tile=tile)
    lp, lz = pl.pallas_call(
        kernel,
        grid=(r, n_tiles),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(tokens, logits)
    return lp, lz
