"""Pure-jnp oracle for the spec-verify gather-logprob kernel.

The verification hot spot is computing log p_j(s_j) and log q_j(s_j) for
every draft position: a log-softmax over the vocab (up to 256k) followed by
a 1-element gather.  Done naively this materializes two full [N, S, V]
softmax arrays in HBM; the kernel streams V tiles through VMEM and emits
only the [N, S] gathered log-probs (plus the log-normalizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def gather_logprobs_ref(logits: Array, tokens: Array) -> tuple[Array, Array]:
    """logits: [R, V]; tokens: i32[R] -> (logprob[R], logz[R]).

    logprob[r] = logits[r, tokens[r]] - logsumexp(logits[r]).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, tokens[:, None], axis=-1)[:, 0]
    return tok - logz, logz
