"""jit'd public wrapper: batched ragged gather-logprobs for verification.

``gather_logprobs(logits [.., V], tokens [..])`` flattens leading dims to
rows, runs the Pallas kernel (interpret=True on CPU; compiled on TPU), and
reshapes back.  Used by the verification server to compute log p_j(s_j) and
log q_j(s_j) without materializing [N, S, V] softmaxes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spec_verify.kernel import gather_logprobs_kernel

Array = jnp.ndarray


def gather_logprobs(logits: Array, tokens: Array, *, tile: int = 2048,
                    interpret: bool = True) -> tuple[Array, Array]:
    """logits [..., V], tokens i32[...] -> (logprob [...], logz [...])."""
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    flat_logits = logits.reshape(-1, v)
    flat_tokens = tokens.reshape(-1).astype(jnp.int32)
    lp, lz = gather_logprobs_kernel(flat_logits, flat_tokens, tile=tile,
                                    interpret=interpret)
    return lp.reshape(lead), lz.reshape(lead)
