"""jit'd public wrapper: batched ragged gather-logprobs for verification.

``gather_logprobs(logits [.., V], tokens [..])`` flattens leading dims to
rows, dispatches on ``impl``, and reshapes back.  Used by the
verification server (``core.speculative.verify(backend="kernel")``) to
compute log p_j(s_j) and log q_j(s_j) without materializing [N, S, V]
softmaxes on TPU.

* ``impl="kernel"`` (default) — the Pallas kernel (compiled on TPU,
  ``interpret=True`` elsewhere);
* ``impl="ref"`` — log-softmax + gather with EXACTLY the operation order
  of ``core.speculative._log_softmax``, so a CPU engine switched between
  verify backends sees bit-identical accept decisions;
* ``impl="auto"`` — kernel on TPU, ref otherwise (what the engine's
  ``attn_backend="kernel"`` flag uses: interpreted Pallas never lands in
  the jit'd serving round off-TPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.spec_verify.kernel import gather_logprobs_kernel

Array = jnp.ndarray


def gather_logprobs(logits: Array, tokens: Array, *, tile: int = 2048,
                    impl: str = "kernel",
                    interpret: Optional[bool] = None) -> tuple[Array, Array]:
    """logits [..., V], tokens i32[...] -> (logprob [...], logz [...])."""
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    flat_logits = logits.reshape(-1, v)
    flat_tokens = tokens.reshape(-1).astype(jnp.int32)
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        # mirror _log_softmax's op order (shift by max, then normalize)
        # bitwise — NOT the ref oracle's tok - logsumexp association
        lp_full = jax.nn.log_softmax(flat_logits.astype(jnp.float32),
                                     axis=-1)
        lp = jnp.take_along_axis(lp_full, flat_tokens[:, None],
                                 axis=-1)[:, 0]
        lz = jax.nn.logsumexp(flat_logits.astype(jnp.float32), axis=-1)
    else:
        if impl != "kernel":
            raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        lp, lz = gather_logprobs_kernel(flat_logits, flat_tokens, tile=tile,
                                        interpret=interpret)
    return lp.reshape(lead), lz.reshape(lead)
