# Pallas TPU kernels backing the serving hot path (kernel.py + ops.py +
# ref.py per package; ops dispatches compiled-on-TPU / fallback-elsewhere).
# ModelConfig.attn_backend="kernel" routes the engine's prefill, decode
# and verify steps here; see docs/ARCHITECTURE.md "Kernel -> engine map".
