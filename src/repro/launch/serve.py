"""GoodSpeed serving launcher.

Runs the full Algorithm-1 loop with real models.  On this CPU container it
uses reduced-dimension variants of the selected architectures; on a TPU
deployment the same entry point takes the full configs (the engine code is
identical — the dry-run proves the full configs lower on the production
meshes).

  PYTHONPATH=src python -m repro.launch.serve \
      --target qwen3-8b --draft olmo-1b --servers 4 --C 16 --rounds 50 \
      --policy goodspeed
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHITECTURES, get_reduced
from repro.core.budget import derive_budget
from repro.data.pipeline import PAPER_DATASETS, SyntheticDomain
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", choices=sorted(ARCHITECTURES),
                    default="qwen3-8b")
    ap.add_argument("--draft", choices=sorted(ARCHITECTURES),
                    default="olmo-1b")
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--C", type=int, default=0,
                    help="verify budget; 0 = derive from the roofline knee")
    ap.add_argument("--s-max", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--policy", choices=("goodspeed", "fixed", "random"),
                    default="goodspeed")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=512)
    args = ap.parse_args()

    tcfg = get_reduced(args.target, vocab_size=args.vocab)
    dcfg = get_reduced(args.draft, vocab_size=args.vocab, d_model=64,
                       num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128)
    target, draft = Model(tcfg), Model(dcfg)
    tp = target.init(jax.random.PRNGKey(args.seed))
    dp = draft.init(jax.random.PRNGKey(args.seed + 1))

    c = args.C or max(args.servers * 2, min(
        derive_budget(args.servers, tcfg.param_count(), 1e4, 2048), 64))
    print(f"target={args.target}(reduced) draft={args.draft}(reduced) "
          f"N={args.servers} C={c} policy={args.policy}")

    rng = np.random.default_rng(args.seed)
    prompts = [SyntheticDomain(PAPER_DATASETS[i % 8], args.vocab, i)
               .sample_prompt(rng)[:16] for i in range(args.servers)]
    temps = tuple(1.0 + 0.5 * (i % 4) for i in range(args.servers))
    eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                          n_servers=args.servers, C=c, s_max=args.s_max,
                          cache_len=1024, policy=args.policy,
                          draft_temps=temps)
    hist = eng.serve(jax.random.PRNGKey(args.seed + 2), prompts, dp, tp,
                     rounds=args.rounds)
    for t, h in enumerate(hist):
        if t % max(1, args.rounds // 10) == 0 or t == len(hist) - 1:
            print(f"round {t:4d}  S={h.S}  accepted={h.accepted}  "
                  f"U={h.utility:7.3f}  alpha={np.round(h.alpha_hat, 2)}")
    tok = np.mean([h.realized.sum() for h in hist])
    print(f"\nmean tokens/round {tok:.2f}   final utility "
          f"{hist[-1].utility:.3f}")


if __name__ == "__main__":
    main()
