"""Training launcher: any assigned architecture on the synthetic pipeline.

CPU (this container): reduced configs, single device.
TPU deployment: pass --full to use the assigned full config; the train_step
is the same function the multi-pod dry-run lowers (TRAIN_RULES sharding:
FSDP over data + tensor parallel over model + Megatron-SP activations).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHITECTURES, get_config, get_reduced
from repro.data.pipeline import token_stream
from repro.models import Model
from repro.training import checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_state import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES),
                    default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (TPU deployments)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(
        args.arch, vocab_size=4096)
    model = Model(cfg)
    print(f"arch={cfg.name}{'' if args.full else ' (reduced)'} "
          f"params~{cfg.param_count() / 1e6:.1f}M")

    opt = AdamW(learning_rate=args.lr, warmup_steps=min(20, args.steps // 5),
                total_steps=args.steps)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    # jaxlint: disable=JL002 — CLI entry point, built once per process
    step = jax.jit(make_train_step(model, opt, remat=args.full))

    t0 = time.time()
    losses = []
    for i, batch in enumerate(token_stream(cfg.vocab_size, args.batch,
                                           args.seq, args.steps)):
        if cfg.frontend is not None:
            print("frontend archs need embeds; use examples/train_lm.py "
                  "pattern"); return
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss={losses[-1]:.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"({time.time() - t0:.0f}s)")
    if args.ckpt:
        checkpoint.save(args.ckpt, state.params,
                        {"step": args.steps, "config": cfg.name})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
