import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is the multi-pod dry-run proper.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs — no allocation — and record
memory_analysis / cost_analysis / collective-byte roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 combos
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHITECTURES, INPUT_SHAPES, get_config,
                           shape_supported)
from repro.distributed.sharding import (BATCH_AXES, CACHE_AXES, SERVE_RULES,
                                        TRAIN_RULES, ShardingContext,
                                        tree_shardings, use_sharding)
from repro.launch.mesh import make_compat_mesh, make_production_mesh
from repro.launch.specs import batch_specs
from repro.models import Model
from repro.training.optimizer import AdamW
from repro.training.train_state import TrainState, make_train_step

# ---------------------------------------------------------------------------
# HLO collective parsing (cost_analysis has no collective term)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the compiled HLO, by kind.

    These are per-PARTITION shapes in SPMD output, i.e. bytes each device
    sends/receives (up to the kind-specific constant factor)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "start" in s.split("=")[-1][:60] and not any(
                f"{c}-start" in s for c in _COLLECTIVES):
            pass
        for kind in _COLLECTIVES:
            # match "= <shape> all-reduce(" and "-start(" forms
            if re.search(rf"=\s+\S+\s+{kind}(-start)?\(", s):
                lhs = s.split("=", 1)[1]
                shape_str = lhs.strip().split(f" {kind}")[0]
                out[kind] += _shape_bytes(shape_str)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Entry-point builders
# ---------------------------------------------------------------------------

def build_train(cfg, ctx: ShardingContext):
    model = Model(cfg)
    opt = AdamW(learning_rate=3e-4)
    step_fn = make_train_step(model, opt, remat=True)
    specs = batch_specs(cfg, "train_4k")

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return TrainState(params=params, opt=opt.init(params))

    state_shape = jax.eval_shape(init_state)
    state_shardings = TrainState(
        params=tree_shardings(ctx, state_shape.params),
        opt=jax.tree.map(
            lambda _: None, state_shape.opt))  # placeholder, fixed below
    # optimizer moments mirror the params' sharding; step is replicated
    from repro.training.optimizer import AdamWState
    p_sh = tree_shardings(ctx, state_shape.params)
    state_shardings = TrainState(
        params=p_sh,
        opt=AdamWState(step=ctx.sharding((), ()), mu=p_sh, nu=p_sh))
    batch_shardings = {
        k: ctx.sharding(BATCH_AXES.get(k, ("batch",) + (None,) * (
            len(v.shape) - 1)), v.shape) for k, v in specs.items()}
    # jaxlint: disable=JL002 — launch-time builder, runs once per shape
    fn = jax.jit(step_fn, in_shardings=(state_shardings, batch_shardings))
    return fn, (state_shape, specs)


def build_prefill(cfg, ctx: ShardingContext):
    model = Model(cfg)
    specs = batch_specs(cfg, "prefill_32k")
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = tree_shardings(ctx, params_shape)

    def prefill(params, batch):
        b, s = batch["tokens"].shape
        kwargs = {}
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if "audio_embeds" in batch:
            kwargs["enc_out"] = model.encode(params, batch["audio_embeds"])
        total = s + (batch["prefix_embeds"].shape[1]
                     if "prefix_embeds" in batch else 0)
        cache = model.init_cache(b, total, jnp.dtype(cfg.dtype))
        out = model.forward(params, batch["tokens"], mode="prefill",
                            cache=cache, **kwargs)
        # next-token logits only: [B, V] (the serving engine samples these)
        return out.logits[:, -1, :], out.cache

    batch_shardings = {
        k: ctx.sharding(BATCH_AXES.get(k, ("batch",) + (None,) * (
            len(v.shape) - 1)), v.shape) for k, v in specs.items()}
    # jaxlint: disable=JL002 — launch-time builder, runs once per shape
    fn = jax.jit(prefill, in_shardings=(p_sh, batch_shardings))
    return fn, (params_shape, specs)


def build_decode(cfg, shape_name, ctx: ShardingContext):
    model = Model(cfg)
    specs = batch_specs(cfg, shape_name)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = tree_shardings(ctx, params_shape)

    def serve_step(params, tokens, positions, cache, cross_kv=None):
        # enc-dec: cross K/V precomputed once at prefill (§Perf it.3) —
        # the decode step must not re-run the encoder per token
        out = model.forward(params, tokens, mode="decode", cache=cache,
                            positions=positions, cross_kv=cross_kv)
        return out.logits, out.cache

    cache_sh = tree_shardings(ctx, specs["cache"], CACHE_AXES)
    args = [p_sh,
            ctx.sharding(("batch", None), specs["tokens"].shape),
            ctx.sharding(("batch", None), specs["positions"].shape),
            cache_sh]
    call_specs = [params_shape, specs["tokens"], specs["positions"],
                  specs["cache"]]
    if "audio_embeds" in specs:
        ckv_shape = jax.eval_shape(
            lambda p, e: model.encode_cross(p, e), params_shape,
            specs["audio_embeds"])
        ckv_sh = jax.tree.map(
            lambda sds: ctx.sharding(
                ("batch", None, "heads", None) if len(sds.shape) == 4
                else (None, "batch", None, "heads", None), sds.shape),
            ckv_shape)
        args.append(ckv_sh)
        call_specs.append(ckv_shape)
    # jaxlint: disable=JL002 — launch-time builder, runs once per shape
    fn = jax.jit(serve_step, in_shardings=tuple(args))
    return fn, call_specs


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def _lower_and_compile(cfg, shape_name, mesh, rules):
    kind = INPUT_SHAPES[shape_name][2]
    t0 = time.time()
    with mesh, use_sharding(mesh, rules) as ctx:
        if kind == "train":
            fn, (state_shape, specs) = build_train(cfg, ctx)
            lowered = fn.lower(state_shape, specs)
        elif shape_name == "prefill_32k":
            fn, (params_shape, specs) = build_prefill(cfg, ctx)
            lowered = fn.lower(params_shape, specs)
        else:
            fn, call_specs = build_decode(cfg, shape_name, ctx)
            lowered = fn.lower(*call_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _costs(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # JAX <= 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops") or 0.0),
            float(cost.get("bytes accessed") or 0.0),
            float(coll["total_bytes"]), coll)


def _calibrated_costs(cfg, shape_name, mesh, rules):
    """XLA cost_analysis counts a while (scan) body ONCE regardless of trip
    count, so scanned stacks under-report.  Compile UNROLLED variants with
    G=1 and G=2 pattern groups and extrapolate linearly to the full depth:
    exact because every cost component is affine in the group count."""
    from repro.models.transformer import stack_layout
    pattern, groups, rest = stack_layout(cfg)
    plen = len(pattern)
    la = plen + len(rest)
    lb = 2 * plen + len(rest)
    cfg_a = dataclasses.replace(cfg, num_layers=la, unroll_scan=True)
    cfg_b = dataclasses.replace(cfg, num_layers=lb, unroll_scan=True)
    ca, _, _ = _lower_and_compile(cfg_a, shape_name, mesh, rules)
    fa, ba, cola, _ = _costs(ca)
    if groups < 2:
        return fa, ba, cola, {"method": "unrolled-exact"}
    cb, _, _ = _lower_and_compile(cfg_b, shape_name, mesh, rules)
    fb, bb, colb, _ = _costs(cb)
    g = groups
    return (fa + (g - 1) * (fb - fa), ba + (g - 1) * (bb - ba),
            cola + (g - 1) * (colb - cola),
            {"method": "unrolled-G1-G2-extrapolated",
             "per_group_flops": fb - fa, "per_group_coll_bytes": colb - cola})


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = "experiments/dryrun",
            debug_mesh: tuple | None = None,
            calibrate: bool = True) -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, dtype="bfloat16", param_dtype="bfloat16")
    if cfg.moe is not None:
        # §Perf it.1e: shard_map expert parallelism (local dispatch +
        # explicit all-to-alls) — 2.8x lower collective traffic than the
        # GSPMD dispatch at compute parity; falls back automatically where
        # divisibility fails (e.g. batch-1 long_500k).
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, shard_map_ep=True))
    ok, why = shape_supported(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "skipped", "reason": why}
    if not ok:
        return record

    if debug_mesh is not None:
        mesh = make_compat_mesh(debug_mesh, ("data", "model"))
        record["mesh"] = mesh_name = "x".join(map(str, debug_mesh))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    kind = INPUT_SHAPES[shape_name][2]
    rules = TRAIN_RULES if kind == "train" else SERVE_RULES

    # 1) the REAL full-depth scanned compile: proves lowering + memory
    compiled, t_lower, t_compile = _lower_and_compile(cfg, shape_name, mesh,
                                                      rules)
    raw_flops, raw_bytes, raw_coll, coll_detail = _costs(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_info = {"error": str(e)}

    # 2) cost calibration via unrolled reduced-depth compiles
    if calibrate:
        flops, bytes_acc, coll_total, calib = _calibrated_costs(
            cfg, shape_name, mesh, rules)
    else:
        flops, bytes_acc, coll_total = raw_flops, raw_bytes, raw_coll
        calib = {"method": "raw-while-body-once"}

    record.update(
        status="ok",
        devices=int(mesh.devices.size),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops=flops, bytes_accessed=bytes_acc,
        collective_total_bytes=coll_total,
        raw={"flops": raw_flops, "bytes_accessed": raw_bytes,
             "collective_total_bytes": raw_coll,
             "collectives": coll_detail},
        calibration=calib,
        memory=mem_info,
        model_params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--debug-mesh", default=None,
                    help="e.g. 2,4 — small (data,model) mesh for CPU debug")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip unrolled cost-calibration compiles (multi-pod "
                         "pass only proves lowering; roofline is single-pod)")
    args = ap.parse_args()
    debug_mesh = tuple(int(x) for x in args.debug_mesh.split(",")) \
        if args.debug_mesh else None

    combos = []
    if args.all:
        for a in sorted(ARCHITECTURES):
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, args.multi_pod, args.out,
                          debug_mesh=debug_mesh,
                          calibrate=not args.no_calibrate)
            msg = rec["status"]
            if rec["status"] == "ok":
                msg += (f" flops={rec['flops']:.3e}"
                        f" coll={rec['collective_total_bytes']:.3e}B"
                        f" compile={rec['compile_s']}s")
            print(f"[dryrun] {arch:24s} {shape:12s} {rec['mesh']:10s} {msg}",
                  flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[dryrun] {arch:24s} {shape:12s} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run combos failed")


if __name__ == "__main__":
    main()
