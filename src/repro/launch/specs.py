"""Input specs per (architecture x input shape): ShapeDtypeStruct stand-ins.

``input_specs(cfg, shape)`` returns the exact pytree each lowered entry
point consumes — weak-type-correct, shardable, no device allocation — the
same pattern the dry-run, roofline, and benchmark harnesses all read from.
Set ``concrete=True`` (smoke tests) to get real random arrays instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, ModelConfig
from repro.models import Model

Array = jnp.ndarray


def _make(shape, dtype, concrete, key=None, maxval=None):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, 0, maxval or 2, dtype)
    return jax.random.normal(key, shape, dtype)


def batch_specs(cfg: ModelConfig, shape_name: str, *, concrete: bool = False,
                batch: int | None = None, seq: int | None = None,
                cache_len: int | None = None, seed: int = 0) -> dict:
    """Input pytree for the given shape's entry point.

    train_4k / prefill_32k -> {"tokens" [B,S], (+frontend embeds)}
    decode_*              -> {"tokens" [B,1], "positions" [B,1],
                              "cache": <stack cache for seq_len context>}
    """
    seq_len, global_batch, kind = INPUT_SHAPES[shape_name]
    b = batch if batch is not None else global_batch
    s = seq if seq is not None else seq_len
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)
    tok_dtype = jnp.int32
    act_dtype = jnp.dtype(cfg.dtype)

    specs: dict = {}
    if kind in ("train", "prefill"):
        n_text = s
        if cfg.frontend == "vision":
            n_text = s - min(cfg.num_prefix_embeds, s // 2)
            specs["prefix_embeds"] = _make(
                (b, s - n_text, cfg.d_model), act_dtype, concrete, keys[1])
        specs["tokens"] = _make((b, n_text), tok_dtype, concrete, keys[0],
                                cfg.vocab_size)
        if cfg.frontend == "audio":
            specs["audio_embeds"] = _make(
                (b, cfg.encoder.source_len, cfg.d_model), act_dtype,
                concrete, keys[2])
        return specs

    # decode: one new token against a seq_len-deep cache
    cl = cache_len if cache_len is not None else s
    specs["tokens"] = _make((b, 1), tok_dtype, concrete, keys[0],
                            cfg.vocab_size)
    specs["positions"] = _make((b, 1), tok_dtype, concrete, keys[3], cl)
    model = Model(cfg)
    if concrete:
        specs["cache"] = model.init_cache(b, cl, act_dtype)
    else:
        specs["cache"] = jax.eval_shape(
            lambda: model.init_cache(b, cl, act_dtype))
    if cfg.frontend == "audio":
        specs["audio_embeds"] = _make(
            (b, cfg.encoder.source_len, cfg.d_model), act_dtype, concrete,
            keys[2])
    return specs
