"""Production mesh definitions (TPU v5e pods).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the pod axis
carries data-parallel replication of verification groups (DESIGN §2).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

import jax


def make_compat_mesh(shape: tuple, names: tuple):
    """``jax.make_mesh`` across JAX versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist from JAX
    0.5.x onward; on older installs (0.4.37 ships in the container) the
    plain call already yields Auto-typed axes, which is what we want.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices for CPU integration tests."""
    return make_compat_mesh((data, model), ("data", "model"))
