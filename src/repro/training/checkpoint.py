"""Minimal-dependency checkpointing: params/opt-state pytrees to .npz.

No orbax offline; this serializes the flattened tree with stable joined-path
keys, plus a metadata json (step, config name).  Restores verify tree
structure and shapes.  Adequate for single-host runs and exact-resume tests;
a production multi-pod deployment would swap in orbax with the same
interface.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, tree, metadata: dict | None = None) -> None:
    flat, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if metadata is not None:
        with open(path.removesuffix(".npz") + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, tree_like):
    """Restore into the structure of ``tree_like`` (e.g. a freshly-inited
    state), verifying every leaf shape."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = _flatten(tree_like)
    leaves = []
    for key, ref in flat.items():
        if key not in npz:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = npz[key]
        if arr.shape != ref.shape:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(path.removesuffix(".npz") + ".meta.json") as f:
        return json.load(f)
