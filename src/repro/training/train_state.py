"""Train step: value_and_grad over lm_loss + AdamW update, jit/pjit-ready."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.training.loss import lm_loss
from repro.training.optimizer import AdamW, AdamWState

Array = jnp.ndarray


class TrainState(NamedTuple):
    params: object
    opt: AdamWState


def make_train_step(model, optimizer: AdamW, remat: bool = True,
                    aux_weight: float = 1.0):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` is a dict with "tokens" [B, S] (+ optional "prefix_embeds" /
    "audio_embeds" for VLM / enc-dec archs).
    """

    def loss_fn(params, batch):
        kwargs = {}
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if "audio_embeds" in batch:
            kwargs["enc_out"] = model.encode(params, batch["audio_embeds"])
        return lm_loss(model, params, batch["tokens"],
                       aux_weight=aux_weight, remat=remat, **kwargs)

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=optimizer.lr_at(opt.step))
        return TrainState(params=params, opt=opt), metrics

    return train_step


def init_train_state(model, optimizer: AdamW, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params))
