"""Causal-LM loss with padded-vocab masking and MoE aux-loss folding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def cross_entropy(logits: Array, labels: Array, mask: Array | None = None,
                  real_vocab: int | None = None) -> Array:
    """Mean next-token cross entropy.

    logits: f32[B, S, Vp] (padded vocab); labels: i32[B, S]; mask: [B, S]
    1.0 on real (non-pad) positions.  Padding vocab entries are excluded
    from the normalizer so the loss matches the unpadded model exactly.
    """
    # Sharded-vocab friendly: only elementwise ops + reductions touch the
    # vocab axis (no take_along_axis gather, no .at[].set with a dense pad
    # constant) so GSPMD keeps the logits vocab-sharded and all-reduces the
    # tiny [B, S] partials instead of all-gathering [B, S, V] f32 logits
    # (~40 GB/step measured before; §Perf it.1c).
    logits = logits.astype(jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    if real_vocab is not None and real_vocab < logits.shape[-1]:
        logits = jnp.where(vocab_iota >= real_vocab, -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def lm_loss(model, params, tokens: Array, *, aux_weight: float = 1.0,
            remat: bool = False, **fwd_kwargs):
    """Shift-by-one LM loss over a token batch; returns (loss, metrics)."""
    out = model.forward(params, tokens[:, :-1], mode="train", remat=remat,
                        **fwd_kwargs)
    logits = out.logits
    # VLM prefix embeddings shift the text positions right; score text only
    p = logits.shape[1] - (tokens.shape[1] - 1)
    logits = logits[:, p:] if p > 0 else logits
    ce = cross_entropy(logits, tokens[:, 1:],
                       real_vocab=model.cfg.vocab_size)
    loss = ce + aux_weight * out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss}
