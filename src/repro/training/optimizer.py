"""AdamW in pure JAX (no optax wheel offline).

Decoupled weight decay (Loshchilov & Hutter), bias-corrected moments,
optional global-norm clipping, cosine/linear LR schedules.  Optimizer state
is a pytree mirroring the params, so it shards with the same rules
(FSDP-style over the data axis; see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AdamWState(NamedTuple):
    step: Array
    mu: object      # first moments (params-shaped pytree)
    nu: object      # second moments


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda a: jnp.zeros_like(a, dtype=jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                          nu=zeros(params))

    def lr_at(self, step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(self.warmup_steps, 1), 1.0)
        if self.schedule == "constant":
            decay = 1.0
        else:
            frac = jnp.clip((s - self.warmup_steps)
                            / jnp.maximum(self.total_steps
                                          - self.warmup_steps, 1), 0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac)) \
                if self.schedule == "cosine" else 1.0 - frac
        return self.learning_rate * warm * decay

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm > 0:
            leaves = jax.tree.leaves(grads)
            gnorm = jnp.sqrt(sum(jnp.sum(
                g.astype(jnp.float32) ** 2) for g in leaves))
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros(())

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr_at(step)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay \
                * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm
