"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma).

All recurrences are written as ``jax.lax.scan`` over time with an explicit
carried state, so the same code serves three modes:

  * train/prefill: scan over the whole sequence from the zero state;
  * verify chunk:  scan over S draft tokens from a checkpointed state
                   (speculative decoding rollback = restore the checkpoint);
  * decode:        scan over a single position.

States are NamedTuple pytrees so they ride through pjit/shard_map and the
serving cache machinery unchanged.

References: xLSTM arXiv:2405.04517 (Eqs. 19-27 mLSTM, 11-18 sLSTM);
RecurrentGemma / Griffin arXiv:2402.19427 (RG-LRU, Eq. 4).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, fully parallelizable gating; scan implementation)
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: Array    # [B, H, dk, dv]  matrix memory
    n: Array    # [B, H, dk]      normalizer
    m: Array    # [B, H]          exponential-gating stabilizer


def mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.d_model * cfg.ssm_proj_factor)
    h = cfg.ssm_num_heads
    return d_in, h, d_in // h


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, h, dk = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_in": _dense_init(ks[0], (d, d_in), d, dtype),
        "w_z": _dense_init(ks[1], (d, d_in), d, dtype),      # output gate path
        "wq_m": _dense_init(ks[2], (d_in, h, dk), d_in, dtype),
        "wk_m": _dense_init(ks[3], (d_in, h, dk), d_in, dtype),
        "wv_m": _dense_init(ks[4], (d_in, h, dk), d_in, dtype),
        "w_if": _dense_init(ks[5], (d_in, h, 2), d_in, dtype),  # i,f gates
        "b_if": jnp.concatenate([jnp.zeros((h, 1)),
                                 jnp.ones((h, 1)) * 3.0], -1).astype(dtype),
        "w_out": _dense_init(ks[6], (d_in, d), d_in, dtype),
    }


def mlstm_zero_state(cfg: ModelConfig, batch: int, dtype) -> MLSTMState:
    d_in, h, dk = mlstm_dims(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, h, dk, dk), jnp.float32),
        n=jnp.zeros((batch, h, dk), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def apply_mlstm(params, x: Array, state: MLSTMState, cfg: ModelConfig):
    """x: [B, S, D] -> (y [B, S, D], new_state)."""
    d_in, h, dk = mlstm_dims(cfg)
    scale = 1.0 / math.sqrt(dk)
    xi = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_z"]))
    q = jnp.einsum("bse,ehk->bshk", xi, params["wq_m"]).astype(jnp.float32)
    k = (jnp.einsum("bse,ehk->bshk", xi, params["wk_m"]) * scale
         ).astype(jnp.float32)
    v = jnp.einsum("bse,ehk->bshk", xi, params["wv_m"]).astype(jnp.float32)
    gates = jnp.einsum("bse,ehg->bshg", xi, params["w_if"]).astype(jnp.float32) \
        + params["b_if"].astype(jnp.float32)
    log_i = gates[..., 0]                       # pre-activation input gate
    log_f = jax.nn.log_sigmoid(gates[..., 1])   # forget gate in log space

    def step(st: MLSTMState, inp):
        qt, kt, vt, li, lf = inp                # [B,H,dk] x3, [B,H] x2
        m_new = jnp.maximum(lf + st.m, li)
        f_eff = jnp.exp(lf + st.m - m_new)[..., None]
        i_eff = jnp.exp(li - m_new)[..., None]
        C = f_eff[..., None] * st.C + i_eff[..., None] * \
            (kt[..., :, None] * vt[..., None, :])
        n = f_eff * st.n + i_eff * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n))
        out = num / jnp.maximum(den, 1.0)[..., None]
        return MLSTMState(C, n, m_new), out

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_i.swapaxes(0, 1), log_f.swapaxes(0, 1))
    state, outs = jax.lax.scan(step, state, xs)
    hcat = outs.swapaxes(0, 1).reshape(x.shape[0], x.shape[1], d_in)
    y = jnp.einsum("bse,ed->bsd", hcat.astype(x.dtype) * z, params["w_out"])
    return y, state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, per-head recurrence)
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: Array    # [B, D] cell
    n: Array    # [B, D] normalizer
    h: Array    # [B, D] hidden (recurrent input)
    m: Array    # [B, D] stabilizer


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    p = {f"w_{g}": _dense_init(ks[i], (d, d), d, dtype)
         for i, g in enumerate(("zi", "ii", "fi", "oi"))}
    # recurrent weights, block-diagonal per head in the paper; dense here
    # with a 1/sqrt(d) init is the same compute shape
    p.update({f"r_{g}": _dense_init(ks[4 + i], (d, d), d, dtype)
              for i, g in enumerate(("z", "i", "f", "o"))})
    p["b_f"] = (jnp.ones((d,)) * 3.0).astype(dtype)
    p["w_out"] = _dense_init(ks[8], (d, d), d, dtype)
    return p


def slstm_zero_state(cfg: ModelConfig, batch: int, dtype) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def apply_slstm(params, x: Array, state: SLSTMState, cfg: ModelConfig):
    zi = jnp.einsum("bsd,de->bse", x, params["w_zi"]).astype(jnp.float32)
    ii = jnp.einsum("bsd,de->bse", x, params["w_ii"]).astype(jnp.float32)
    fi = (jnp.einsum("bsd,de->bse", x, params["w_fi"])
          + params["b_f"]).astype(jnp.float32)
    oi = jnp.einsum("bsd,de->bse", x, params["w_oi"]).astype(jnp.float32)
    rz, ri, rf, ro = (params[k].astype(jnp.float32)
                      for k in ("r_z", "r_i", "r_f", "r_o"))

    def step(st: SLSTMState, inp):
        z_x, i_x, f_x, o_x = inp
        z = jnp.tanh(z_x + st.h @ rz)
        li = i_x + st.h @ ri                      # log-space input gate
        lf = jax.nn.log_sigmoid(f_x + st.h @ rf)  # log forget gate
        o = jax.nn.sigmoid(o_x + st.h @ ro)
        m_new = jnp.maximum(lf + st.m, li)
        c = jnp.exp(lf + st.m - m_new) * st.c + jnp.exp(li - m_new) * z
        n = jnp.exp(lf + st.m - m_new) * st.n + jnp.exp(li - m_new)
        h = o * c / jnp.maximum(n, 1.0)
        return SLSTMState(c, n, h, m_new), h

    xs = (zi.swapaxes(0, 1), ii.swapaxes(0, 1), fi.swapaxes(0, 1),
          oi.swapaxes(0, 1))
    state, outs = jax.lax.scan(step, state, xs)
    h = outs.swapaxes(0, 1).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", h, params["w_out"]), state


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: Array        # [B, d_rnn]                   linear-recurrence state
    conv: Array     # [B, conv_width-1, d_rnn]     temporal-conv lookback


def rglru_dims(cfg: ModelConfig):
    return cfg.rglru_d_rnn or cfg.d_model


def init_rglru(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    dr = rglru_dims(cfg)
    w = cfg.conv1d_width
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)*r) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(-jnp.log(
        jax.random.uniform(ks[0], (dr,), minval=0.9, maxval=0.999))
        / 8.0))
    return {
        "w_x": _dense_init(ks[1], (d, dr), d, dtype),       # recurrent branch
        "w_gate": _dense_init(ks[2], (d, dr), d, dtype),    # gelu gate branch
        "conv_w": _dense_init(ks[3], (w, dr), w, dtype),    # depthwise conv
        "w_a": _dense_init(ks[4], (dr, dr), dr, dtype),     # recurrence gate
        "w_i": _dense_init(ks[5], (dr, dr), dr, dtype),     # input gate
        "lambda_param": lam.astype(jnp.float32),
        "w_out": _dense_init(ks[6], (dr, d), dr, dtype),
    }


def rglru_zero_state(cfg: ModelConfig, batch: int, dtype) -> RGLRUState:
    dr = rglru_dims(cfg)
    return RGLRUState(
        h=jnp.zeros((batch, dr), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, dr), dtype),
    )


_RGLRU_C = 8.0


def apply_rglru(params, x: Array, state: RGLRUState, cfg: ModelConfig):
    """Griffin recurrent block: proj -> causal conv1d -> RG-LRU, times a
    gelu gate branch, then out-proj.  x: [B, S, D]."""
    b, s, _ = x.shape
    w = cfg.conv1d_width
    xb = jnp.einsum("bsd,de->bse", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate"]))

    # causal depthwise conv with carried lookback
    ext = jnp.concatenate([state.conv.astype(xb.dtype), xb], axis=1)
    conv = sum(ext[:, i:i + s, :] * params["conv_w"][w - 1 - i]
               for i in range(w))
    new_conv = ext[:, -(w - 1):, :] if w > 1 else state.conv

    # RG-LRU recurrence (fp32)
    u = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, params["w_a"].astype(jnp.float32)))
    i_g = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, params["w_i"].astype(jnp.float32)))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lambda_param"]) * r  # [B,S,dr]
    a = jnp.exp(log_a)
    gated_x = u * i_g
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))

    def step(h, inp):
        a_t, gx_t, m_t = inp
        h = a_t * h + m_t * gx_t
        return h, h

    xs = (a.swapaxes(0, 1), gated_x.swapaxes(0, 1), mult.swapaxes(0, 1))
    h_final, hs = jax.lax.scan(step, state.h, xs)
    y = hs.swapaxes(0, 1).astype(x.dtype) * gate
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, RGLRUState(h=h_final, conv=new_conv)
