"""Foundational layers: norms, MLPs, embeddings, rotary embeddings.

Everything is pure-functional: ``init_*`` builds a param dict (leaves are
jnp arrays), ``apply`` is a free function.  Param trees use descriptive leaf
names that the sharding rules in ``repro.distributed.sharding`` match on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(in_axis_size)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, d, norm_type: str, dtype):
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if norm_type == "nonparam_ln":  # OLMo: no learnable affine
        return {}
    raise ValueError(norm_type)


def apply_norm(params, x: Array, norm_type: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf / rms * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) / jnp.sqrt(var + eps)
        if norm_type == "layernorm":
            out = out * params["scale"].astype(jnp.float32) \
                + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_head_norm(key, head_dim, dtype):
    """Per-head RMSNorm scale for qk-norm (Qwen3)."""
    return {"scale": jnp.ones((head_dim,), dtype)}


def apply_head_norm(params, x: Array, eps: float = 1e-6) -> Array:
    """x: [..., head_dim]"""
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf / rms * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(k1, (d_model, d_ff), d_model, dtype),
            "w_up": _dense_init(k2, (d_model, d_ff), d_model, dtype),
            "w_down": _dense_init(k3, (d_ff, d_model), d_ff, dtype),
        }
    return {  # plain 2-layer (Whisper: gelu)
        "w_up": _dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w_down": _dense_init(k2, (d_ff, d_model), d_ff, dtype),
    }


def apply_mlp(params, x: Array, act: str) -> Array:
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype):
    return {"embedding": (jax.random.normal(key, (vocab, d_model)) * 0.02
                          ).astype(dtype)}


def apply_embedding(params, tokens: Array) -> Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def init_unembed(key, d_model, vocab, dtype):
    return {"w_unembed": _dense_init(key, (d_model, vocab), d_model, dtype)}


def apply_unembed(params, x: Array) -> Array:
    return jnp.einsum("...d,dv->...v", x, params["w_unembed"])


def init_learned_pos(key, max_len, d_model, dtype):
    return {"pos_embedding": (jax.random.normal(key, (max_len, d_model))
                              * 0.02).astype(dtype)}


def apply_learned_pos(params, x: Array, positions: Array) -> Array:
    table = params["pos_embedding"]
    pos = jnp.clip(positions, 0, table.shape[0] - 1)
    return x + jnp.take(table, pos, axis=0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (partial-rotary capable, StableLM rope_pct)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, rope_pct: float, theta: float):
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    return inv, rot_dim


def apply_rope(x: Array, positions: Array, rope_pct: float, theta: float) -> Array:
    """x: [B, S, H, head_dim]; positions: [B, S] absolute positions."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, rope_pct, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


def softcap(x: Array, cap: float) -> Array:
    """tanh soft-capping (Gemma / RecurrentGemma logits)."""
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap
