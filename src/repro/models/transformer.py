"""Block composition and the scanned decoder stack.

A *block* is one residual unit: pre-norm attention (full/sliding/local or
MLA) + pre-norm MLP/MoE, or a recurrent unit (mLSTM / sLSTM / RG-LRU).
``block_pattern`` from the config is cycled over ``num_layers``; the stack
is executed as ``jax.lax.scan`` over pattern *groups* (all params stacked
[G, ...]) so compile time is O(pattern) not O(layers) — essential for the
94-layer MoE on a 512-device dry-run.  A remainder of ``num_layers mod
pattern`` trailing layers runs unscanned.

Modes (static):
  train   — no cache; attention is causal within the chunk.
  prefill — bulk-writes an empty cache, returns it (inference prefill).
  decode  — appends an S-token chunk (S=1 plain decode; S=draft-length for
            speculative verification) to the cache and attends over it.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import ssm
from repro.kernels.flash_prefill import flash_prefill
from repro.models.attention import (apply_cross_attention, attention_out,
                                    attention_qkv, decode_cache_attention,
                                    dot_attention, init_attention, init_mla,
                                    mla_attend, mla_project)
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe
from repro.serving.kv_cache import (PagedMLACache, init_attn_cache,
                                    init_mla_cache, init_paged_attn_cache,
                                    init_paged_mla_cache, paged_view,
                                    write_chunk, write_prefill)

Array = jnp.ndarray

ATTN_KINDS = ("attn", "sliding_attn", "local_attn")


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(ks[0], cfg.d_model,
                                            cfg.norm_type, dtype)}
    if kind in ATTN_KINDS:
        if cfg.mla is not None:
            p["attn"] = init_mla(ks[1], cfg, dtype)
        else:
            p["attn"] = init_attention(ks[1], cfg, dtype)
        if cfg.d_ff > 0:
            if not cfg.parallel_block:
                p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type, dtype)
            if cfg.moe is not None:
                p["moe"] = init_moe(ks[3], cfg, dtype)
            else:
                p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                    cfg.mlp_act, dtype)
    elif kind == "mlstm":
        p["core"] = ssm.init_mlstm(ks[1], cfg, dtype)
    elif kind == "slstm":
        p["core"] = ssm.init_slstm(ks[1], cfg, dtype)
        if cfg.d_ff > 0:
            p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type, dtype)
            p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                                dtype)
    elif kind == "rglru":
        p["core"] = ssm.init_rglru(ks[1], cfg, dtype)
        if cfg.d_ff > 0:
            p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type, dtype)
            p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                                dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def init_block_cache(kind: str, cfg: ModelConfig, batch: int,
                     cache_len: int, dtype, ring_headroom: int = 0,
                     paged: bool = False, block_size: int = 16,
                     num_blocks: int = 0):
    """Zero cache/state for one block.  cache_len applies to attention kinds;
    sliding/local kinds allocate min(cache_len, window) ring buffers.

    ring_headroom: extra ring slots beyond the window.  ``write_chunk``
    commits a whole S-token decode chunk BEFORE attention runs, so a ring
    sized exactly ``window`` evicts up to S-1 of the oldest keys the
    chunk's first queries still need.  Chunked-decode callers (the
    speculative verify path) must pass ``chunk_len - 1`` headroom; the
    window mask keeps the extra older keys out of attention.

    paged: full-attention kinds allocate block-pool caches (GQA or MLA)
    with the given block_size / pool size (num_blocks = 0 auto-sizes; see
    ``init_paged_attn_cache``).  Ring and recurrent kinds are already
    O(window)/O(1) per row and keep their static layouts."""
    if kind in ATTN_KINDS:
        ring = _is_ring(kind, cfg)
        length = (min(cache_len, cfg.window) + ring_headroom) if ring \
            else cache_len
        if paged and not ring:
            if cfg.mla is not None:
                return init_paged_mla_cache(
                    batch, length, cfg.mla.kv_lora_rank,
                    cfg.mla.qk_rope_head_dim, dtype, block_size, num_blocks)
            return init_paged_attn_cache(
                batch, length, cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype, block_size, num_blocks)
        if cfg.mla is not None:
            return init_mla_cache(batch, length, cfg.mla.kv_lora_rank,
                                  cfg.mla.qk_rope_head_dim, dtype)
        return init_attn_cache(batch, length, cfg.num_kv_heads,
                               cfg.resolved_head_dim, dtype)
    if kind == "mlstm":
        return ssm.mlstm_zero_state(cfg, batch, dtype)
    if kind == "slstm":
        return ssm.slstm_zero_state(cfg, batch, dtype)
    if kind == "rglru":
        return ssm.rglru_zero_state(cfg, batch, dtype)
    raise ValueError(kind)


def _is_ring(kind: str, cfg: ModelConfig) -> bool:
    return kind in ("sliding_attn", "local_attn") and cfg.window > 0


def _window_for(kind: str, cfg: ModelConfig) -> int:
    if kind in ("sliding_attn", "local_attn") and cfg.window > 0:
        return cfg.window
    return 0


def _attend(params, kind, cfg: ModelConfig, x_norm, positions, cache, mode,
            chunk_valid, causal=True, shared_blocks=None, shared_lens=None):
    """Attention sublayer in all modes; returns (ctx_out, new_cache).

    ``shared_blocks``/``shared_lens`` (prefill + paged cache only) attach
    an already-cached shared prompt prefix per row; the chunk then holds
    only each row's unique suffix and ``positions`` carries the suffix's
    absolute positions (see ``paged_write_prefill``)."""
    window = _window_for(kind, cfg)
    ring = _is_ring(kind, cfg)
    b, s, _ = x_norm.shape

    if cfg.mla is not None:
        chunk = mla_project(params["attn"], x_norm, cfg, positions)
        if mode == "train":
            kv_pos = positions
            valid = chunk_valid if chunk_valid is not None \
                else jnp.ones((b, s), bool)
            # train: attend over the chunk's own latents
            out = mla_attend(params["attn"], chunk, chunk.c_kv, chunk.k_pe,
                             cfg, positions, kv_pos, valid)  # always causal
            return out, None
        if mode == "prefill":
            lengths = chunk_valid.sum(-1).astype(jnp.int32) if chunk_valid \
                is not None else jnp.full((b,), s, jnp.int32)
            cache = write_prefill(cache, (chunk.c_kv, chunk.k_pe), lengths,
                                  ring=ring, shared_blocks=shared_blocks,
                                  shared_lens=shared_lens)
        else:
            cache = write_chunk(cache, (chunk.c_kv, chunk.k_pe), chunk_valid,
                                ring=ring)
        if isinstance(cache, PagedMLACache):
            ckv_all, kpe_all = paged_view(cache)
        else:
            ckv_all, kpe_all = cache.ckv, cache.kpe
        valid = cache.pos_arr >= 0
        out = mla_attend(params["attn"], chunk, ckv_all, kpe_all, cfg,
                         positions, cache.pos_arr, valid)
        return out, cache

    q, k, v = attention_qkv(params["attn"], x_norm, cfg, positions)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    if mode == "train":
        valid = chunk_valid if chunk_valid is not None \
            else jnp.ones((b, s), bool)
        ctx = dot_attention(q, k, v, positions, positions, valid,
                            window=window, softcap=cfg.logit_softcap,
                            causal=causal)
        return attention_out(params["attn"], ctx), None
    if mode == "prefill":
        lengths = chunk_valid.sum(-1).astype(jnp.int32) if chunk_valid \
            is not None else jnp.full((b,), s, jnp.int32)
        cache = write_prefill(cache, (k, v), lengths, ring=ring,
                              shared_blocks=shared_blocks,
                              shared_lens=shared_lens)
        if cfg.attn_backend == "kernel" and not ring \
                and cfg.logit_softcap == 0.0 and shared_blocks is None:
            # (with an attached shared prefix the keys a query needs are
            # NOT all inside the chunk, so the chunk-only kernel is
            # wrong; shared prefill reads the just-written cache instead)
            # kernel prefill: chunk-causal self-attention over (q, k, v)
            # directly.  Valid rows are left-aligned prefixes, so every
            # key a valid query may attend (kv_pos <= q_pos) is inside
            # the chunk — identical to attending over the just-written
            # cache.  Ring layers keep the cache path (their prefill may
            # evict early keys, a semantic the chunk kernel lacks).
            ctx = flash_prefill(q, k, v, impl="auto").astype(q.dtype)
            return attention_out(params["attn"], ctx), cache
        ctx = decode_cache_attention(q, cache, positions, window=window,
                                     softcap=cfg.logit_softcap,
                                     backend="jnp")
    else:
        cache = write_chunk(cache, (k, v), chunk_valid, ring=ring)
        ctx = decode_cache_attention(q, cache, positions, window=window,
                                     softcap=cfg.logit_softcap,
                                     backend=cfg.attn_backend)
    return attention_out(params["attn"], ctx), cache


def apply_block(params, kind: str, cfg: ModelConfig, x: Array,
                positions: Array, cache, mode: str,
                chunk_valid: Optional[Array] = None, causal: bool = True,
                xattn_params=None, enc_out=None, cross_kv=None,
                shared_blocks=None, shared_lens=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["norm1"], x, cfg.norm_type)

    if kind in ATTN_KINDS:
        attn_out, cache = _attend(params, kind, cfg, h, positions, cache,
                                  mode, chunk_valid, causal=causal,
                                  shared_blocks=shared_blocks,
                                  shared_lens=shared_lens)
        if cfg.parallel_block and cfg.d_ff > 0:
            mlp_out = apply_mlp(params["mlp"], h, cfg.mlp_act) \
                if "mlp" in params else 0.0
            x = x + attn_out + mlp_out
        else:
            x = x + attn_out
            if xattn_params is not None and (enc_out is not None
                                             or cross_kv is not None):
                from repro.models.attention import encode_cross_kv
                hx = apply_norm(xattn_params["norm_x"], x, cfg.norm_type)
                if cross_kv is not None:
                    # §Perf it.3: serving path — cross K/V precomputed once
                    # at prefill instead of re-projected every decode step
                    ek, ev = cross_kv
                else:
                    ek, ev = encode_cross_kv(xattn_params["xattn"], enc_out)
                x = x + apply_cross_attention(xattn_params["xattn"], hx,
                                              ek, ev, cfg)
            if cfg.d_ff > 0:
                h2 = apply_norm(params["norm2"], x, cfg.norm_type)
                if cfg.moe is not None:
                    y, aux = apply_moe(params["moe"], h2, cfg)
                else:
                    y = apply_mlp(params["mlp"], h2, cfg.mlp_act)
                x = x + y
        x = constrain(x, "batch", "seq", "embed")
        return x, cache, aux

    # recurrent kinds — train mode starts from (and discards) the zero state
    discard_state = cache is None
    if discard_state:
        cache = init_block_cache(kind, cfg, x.shape[0], 0, x.dtype)
    if kind == "mlstm":
        y, cache = ssm.apply_mlstm(params["core"], h, cache, cfg)
        x = x + y
    elif kind == "slstm":
        y, cache = ssm.apply_slstm(params["core"], h, cache, cfg)
        x = x + y
    elif kind == "rglru":
        y, cache = ssm.apply_rglru(params["core"], h, cache, cfg)
        x = x + y
    if cfg.d_ff > 0 and "mlp" in params:
        h2 = apply_norm(params["norm2"], x, cfg.norm_type)
        x = x + apply_mlp(params["mlp"], h2, cfg.mlp_act)
    x = constrain(x, "batch", "seq", "embed")
    return x, (None if discard_state else cache), aux


# ---------------------------------------------------------------------------
# Stack: scan over pattern groups
# ---------------------------------------------------------------------------

def stack_layout(cfg: ModelConfig):
    pattern = cfg.block_pattern
    plen = len(pattern)
    groups = cfg.num_layers // plen
    rest = tuple(pattern[i] for i in range(cfg.num_layers - groups * plen))
    return pattern, groups, rest


def init_stack(key, cfg: ModelConfig, dtype):
    pattern, groups, rest = stack_layout(cfg)
    keys = jax.random.split(key, len(pattern) + len(rest))
    params = {"scan": {}, "rest": {}}
    for i, kind in enumerate(pattern):
        gkeys = jax.random.split(keys[i], groups)
        params["scan"][f"slot{i}"] = jax.vmap(
            lambda k, kind=kind: init_block(k, kind, cfg, dtype))(gkeys)
    for j, kind in enumerate(rest):
        params["rest"][f"layer{j}"] = init_block(keys[len(pattern) + j],
                                                 kind, cfg, dtype)
    return params


def init_stack_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                     ring_headroom: int = 0, paged: bool = False,
                     block_size: int = 16, num_blocks: int = 0):
    pattern, groups, rest = stack_layout(cfg)
    cache = {"scan": {}, "rest": {}}
    # groups == 0 (num_layers < pattern length): apply_stack skips the scan
    # entirely and returns scan={}, so the init structure must match or
    # row-merge admission on a cold-start cache hits a treedef mismatch.
    for i, kind in enumerate(pattern if groups > 0 else ()):
        one = init_block_cache(kind, cfg, batch, cache_len, dtype,
                               ring_headroom, paged, block_size, num_blocks)
        cache["scan"][f"slot{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (groups,) + a.shape), one)
    for j, kind in enumerate(rest):
        cache["rest"][f"layer{j}"] = init_block_cache(kind, cfg, batch,
                                                      cache_len, dtype,
                                                      ring_headroom, paged,
                                                      block_size, num_blocks)
    return cache


def apply_stack(params, cfg: ModelConfig, x: Array, positions: Array,
                cache, mode: str, chunk_valid: Optional[Array] = None,
                remat: bool = False, causal: bool = True, enc_out=None,
                cross_params=None, cross_kv=None, shared_blocks=None,
                shared_lens=None):
    """Run the whole stack.  cache may be None (train).  Returns
    (x, new_cache, total_aux).  ``shared_blocks``/``shared_lens`` are
    loop-invariant (like ``chunk_valid``): the deterministic first-free
    allocator gives every layer the identical block table, so one set of
    shared physical block ids is valid for all layers."""
    pattern, groups, rest = stack_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def group_body(carry, xs):
        x, aux = carry
        slot_params, slot_caches, slot_cross, slot_ckv = xs
        new_caches = {}
        for i, kind in enumerate(pattern):
            c_in = slot_caches[f"slot{i}"] if slot_caches is not None else None
            xp = slot_cross[f"slot{i}"] if slot_cross is not None else None
            ckv = slot_ckv[f"slot{i}"] if slot_ckv is not None else None
            x, c_out, a = apply_block(slot_params[f"slot{i}"], kind, cfg, x,
                                      positions, c_in, mode, chunk_valid,
                                      causal=causal, xattn_params=xp,
                                      enc_out=enc_out, cross_kv=ckv,
                                      shared_blocks=shared_blocks,
                                      shared_lens=shared_lens)
            new_caches[f"slot{i}"] = c_out
            aux = aux + a
        return (x, aux), (new_caches if slot_caches is not None else 0)

    body = jax.checkpoint(group_body) if remat else group_body

    if groups > 0:
        scan_caches = cache["scan"] if cache is not None else None
        scan_cross = cross_params["scan"] if cross_params is not None else None
        scan_ckv = cross_kv["scan"] if cross_kv is not None else None
        if cfg.unroll_scan:
            # dry-run cost calibration path: python loop instead of scan
            carry = (x, aux_total)
            ys = []
            for g in range(groups):
                xs_g = jax.tree.map(lambda a: a[g],
                                    (params["scan"], scan_caches, scan_cross,
                                     scan_ckv))
                carry, y = body(carry, xs_g)
                ys.append(y)
            (x, aux_total) = carry
            new_scan = jax.tree.map(lambda *a: jnp.stack(a), *ys) \
                if (ys and scan_caches is not None) else {}
        else:
            (x, aux_total), new_scan = jax.lax.scan(
                body, (x, aux_total), (params["scan"], scan_caches,
                                       scan_cross, scan_ckv))
    else:
        new_scan = {}

    new_rest = {}
    for j, kind in enumerate(rest):
        c_in = cache["rest"][f"layer{j}"] if cache is not None else None
        xp = cross_params["rest"][f"layer{j}"] if cross_params is not None \
            else None
        ckv = cross_kv["rest"][f"layer{j}"] if cross_kv is not None else None
        x, c_out, a = apply_block(params["rest"][f"layer{j}"], kind, cfg, x,
                                  positions, c_in, mode, chunk_valid,
                                  causal=causal, xattn_params=xp,
                                  enc_out=enc_out, cross_kv=ckv,
                                  shared_blocks=shared_blocks,
                                  shared_lens=shared_lens)
        new_rest[f"layer{j}"] = c_out
        aux_total = aux_total + a

    new_cache = None if cache is None else {"scan": new_scan,
                                            "rest": new_rest}
    return x, new_cache, aux_total
