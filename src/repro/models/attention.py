"""Attention: GQA/MHA (+qk-norm, partial rope), sliding/local windows, MLA,
cross-attention — with a single blockwise (flash-style) inner loop.

Layout conventions:
  activations  x        [B, S, D]
  queries      q        [B, S, H, hd]
  keys/values  k, v     [B, L, KV, hd]      (L = kv length: seq or cache)
  positions              [B, S] absolute token positions (ring buffers and
                         padded caches are handled with explicit kv position
                         + validity arrays, so masks never assume layout)

The inner loop ``dot_attention`` scans over KV blocks with an online-softmax
accumulator (flash attention in pure jnp).  This keeps the prefill memory
footprint at O(S·block) instead of O(S²) — required for the 32k prefill
shape — and is also the jnp oracle for the Pallas kernels in
``repro.kernels``.

Paged caches (block-pool storage; see docs/KV_CACHE.md) attend through
``paged_dot_attention``: the per-row block table gathers a logical
[B, L, KV, hd] view of the pool, after which the same masking contract
(explicit kv positions + validity) applies unchanged.

``decode_cache_attention`` is the serving decode entry point: it
dispatches on cache type AND on ``ModelConfig.attn_backend`` — under
``"kernel"`` paged GQA caches go to the block-table-native
``repro.kernels.paged_decode`` kernel (no gathered view at all) and
static/ring GQA caches to ``repro.kernels.decode_attention``; anything
a kernel doesn't cover (MLA latents) degrades to the jnp core.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode import paged_flash_decode
from repro.models.layers import (_dense_init, apply_head_norm, apply_rope,
                                 init_head_norm)
from repro.serving.kv_cache import (AttnCache, PAGED_TYPES, PagedAttnCache,
                                    paged_view)

Array = jnp.ndarray

NEG_INF = -1e30


def _pick_block(l: int) -> int:
    for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if l % b == 0:
            return b
    return 1


def dot_attention(
    q: Array,               # [B, Sq, H, hd]
    k: Array,               # [B, L, KV, hd]
    v: Array,               # [B, L, KV, hd]
    q_pos: Array,           # [B, Sq] absolute positions of queries
    kv_pos: Array,          # [B, L]  absolute positions of keys
    kv_valid: Array,        # [B, L]  bool: cache slot holds a real token
    window: int = 0,        # >0: only attend to q_pos - kv_pos < window
    causal: bool = True,
    softcap: float = 0.0,
    block_size: int = 0,
) -> Array:
    """Blockwise online-softmax attention.  Returns [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    l, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]  # value dim may differ from qk dim (MLA)
    groups = h // kv
    scale = 1.0 / math.sqrt(hd)
    # Decode/verify chunks (small Sq) use ONE block: scores [B,Sq,H,L] are
    # small, and a single einsum lets GSPMD flash-decode a cache whose L axis
    # is sharded over the model axis (partial softmax stats + all-reduce)
    # instead of dynamic-slicing across shards.  Long-chunk prefill/train
    # scans KV blocks with the online-softmax accumulator (memory O(S*blk)).
    blk = block_size or (l if sq <= 64 else _pick_block(l))
    n_blocks = l // blk

    # operands stay in their storage dtype (bf16 on TPU) with f32 MXU
    # accumulation via preferred_element_type — upcasting k/v here would
    # materialize an f32 copy of the whole cache (2x HBM traffic; §Perf 2b)
    qf = (q * scale).reshape(b, sq, kv, groups, hd)

    def mask_for(kpos, kvalid):
        # [B, Sq, blk]
        m = kvalid[:, None, :]
        if causal:
            m = m & (kpos[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            m = m & (q_pos[:, :, None] - kpos[:, None, :] < window)
        return m

    def block(carry, i):
        m_prev, l_prev, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * blk, blk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * blk, blk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, i * blk, blk, axis=1)
        kval = jax.lax.dynamic_slice_in_dim(kv_valid, i * blk, blk, axis=1)
        # scores: [B, Sq, KV, G, blk] (f32 accumulation)
        s = jnp.einsum("bqkgh,blkh->bqkgl", qf, ks,
                       preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = mask_for(kp, kval)[:, :, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # explicit zero for masked slots: when a row is ENTIRELY masked,
        # s == m_new == NEG_INF would give p = exp(0) = 1 (mean-of-v bug)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgl,blkh->bqkgh", p.astype(v.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, sq, kv, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, groups), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, groups, vd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(block, (m0, l0, a0),
                                      jnp.arange(n_blocks))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, sq, h, vd).astype(q.dtype)


def paged_dot_attention(q: Array, cache, q_pos: Array,
                        softcap: float = 0.0) -> Array:
    """Attention over a ``PagedAttnCache``: gather the block-table view of
    the K/V pools, then run the standard blockwise core.  Paged caches are
    full-attention only (sliding-window layers keep O(window) ring
    buffers), so there is no window argument."""
    k, v = paged_view(cache)
    return dot_attention(q, k, v, q_pos, cache.pos_arr,
                         cache.pos_arr >= 0, softcap=softcap)


def decode_cache_attention(q: Array, cache, q_pos: Array, *,
                           window: int = 0, softcap: float = 0.0,
                           backend: str = "jnp") -> Array:
    """Decode-mode GQA attention over an already-updated cache, dispatched
    on cache type and ``backend`` (= ``ModelConfig.attn_backend``):

    * ``"kernel"`` + ``PagedAttnCache`` -> block-table-native
      ``paged_flash_decode`` (never materializes the ``paged_view``);
    * ``"kernel"`` + ``AttnCache`` (static or ring) -> ``flash_decode``,
      same position-based masking as ``dot_attention``;
    * ``"jnp"`` -> the blockwise jnp core (gathered view for paged).

    MLA decode never reaches this function — it stays on the absorbed
    latent path (``mla_attend``) regardless of backend.  ``impl="auto"``
    inside the kernel ops compiles the Pallas kernel on TPU and runs the
    fused jnp fallbacks elsewhere, so the dispatch is safe on any
    platform."""
    if backend == "kernel":
        if isinstance(cache, PagedAttnCache):
            return paged_flash_decode(q, cache, q_pos, softcap=softcap,
                                      impl="auto").astype(q.dtype)
        if isinstance(cache, AttnCache):
            return flash_decode(q, cache, q_pos=q_pos, window=window,
                                softcap=softcap,
                                impl="auto").astype(q.dtype)
    if isinstance(cache, PAGED_TYPES):
        return paged_dot_attention(q, cache, q_pos, softcap=softcap)
    return dot_attention(q, cache.k, cache.v, q_pos, cache.pos_arr,
                         cache.pos_arr >= 0, window=window, softcap=softcap)


# ---------------------------------------------------------------------------
# Standard GQA attention block
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, kv, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, kv, hd), d, dtype),
        "wo": _dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_head_norm(ks[4], hd, dtype)
        p["k_norm"] = init_head_norm(ks[5], hd, dtype)
    return p


def attention_qkv(params, x: Array, cfg: ModelConfig, positions: Array):
    """Project to rotated q, k, v for the current chunk."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = apply_head_norm(params["q_norm"], q)
        k = apply_head_norm(params["k_norm"], k)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
    return q, k, v


def attention_out(params, ctx: Array) -> Array:
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = _dense_init(ks[0], (d, m.q_lora_rank), d, dtype)
        p["wq_b"] = _dense_init(ks[1], (m.q_lora_rank, h, qk_dim),
                                m.q_lora_rank, dtype)
    else:
        p["wq"] = _dense_init(ks[0], (d, h, qk_dim), d, dtype)
    # joint compression of keys/values into the latent + decoupled rope key
    p["wkv_a"] = _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                             d, dtype)
    p["wk_b"] = _dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim),
                            m.kv_lora_rank, dtype)
    p["wv_b"] = _dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim),
                            m.kv_lora_rank, dtype)
    p["wo"] = _dense_init(ks[5], (h, m.v_head_dim, d), h * m.v_head_dim, dtype)
    return p


class MLAChunk(NamedTuple):
    q_nope: Array   # [B, S, H, nope]
    q_pe: Array     # [B, S, H, rope]
    c_kv: Array     # [B, S, r]        latent to cache
    k_pe: Array     # [B, S, rope]     shared rope key to cache


def mla_project(params, x: Array, cfg: ModelConfig, positions: Array) -> MLAChunk:
    m: MLAConfig = cfg.mla
    if m.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
        q = jnp.einsum("bsr,rhk->bshk", q, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, 1.0, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_pe = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    k_pe = apply_rope(k_pe[:, :, None, :], positions, 1.0,
                      cfg.rope_theta)[:, :, 0, :]
    return MLAChunk(q_nope, q_pe, c_kv, k_pe)


def mla_attend(params, chunk: MLAChunk, c_kv: Array, k_pe: Array,
               cfg: ModelConfig, q_pos: Array, kv_pos: Array,
               kv_valid: Array) -> Array:
    """Attention over the latent cache.  c_kv: [B, L, r], k_pe: [B, L, rope].

    Two mathematically identical paths:
    * prefill/train (large Sq): up-project latents to per-head K/V once and
      run the blockwise flash core — the up-projection amortizes over Sq.
    * decode/verify (small Sq): ABSORBED form (§Perf it.2, DeepSeek-V2's
      matrix-absorption): fold W_uk into the query and W_uv into the output
      so attention runs directly in the rank-r latent space — per step this
      replaces O(L·r·H·(nope+v)) up-projection FLOPs + an [B,L,H,d] K/V
      materialization with O(H·nope·r) query-side work.
    """
    m: MLAConfig = cfg.mla
    b, s = chunk.q_nope.shape[:2]
    if s <= 64:
        return _mla_attend_absorbed(params, chunk, c_kv, k_pe, cfg, q_pos,
                                    kv_pos, kv_valid)
    k_nope = jnp.einsum("blr,rhk->blhk", c_kv, params["wk_b"])
    v = jnp.einsum("blr,rhk->blhk", c_kv, params["wv_b"])
    k_pe_h = jnp.broadcast_to(k_pe[:, :, None, :],
                              k_pe.shape[:2] + (cfg.num_heads,
                                                m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_pe_h], axis=-1)
    q = jnp.concatenate([chunk.q_nope, chunk.q_pe], axis=-1)
    ctx = dot_attention(q, k, v, q_pos, kv_pos, kv_valid,
                        softcap=cfg.logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


def _mla_attend_absorbed(params, chunk: MLAChunk, c_kv: Array, k_pe: Array,
                         cfg: ModelConfig, q_pos: Array, kv_pos: Array,
                         kv_valid: Array) -> Array:
    """Latent-space attention: scores and context never leave rank r."""
    m: MLAConfig = cfg.mla
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_dim)
    # fold W_uk into the query: [B,S,H,nope] x [r,H,nope] -> [B,S,H,r];
    # bf16 operands + f32 accumulation (no f32 copy of the latent cache)
    q_abs = jnp.einsum("bshk,rhk->bshr", chunk.q_nope, params["wk_b"],
                       preferred_element_type=jnp.float32)
    s_nope = jnp.einsum("bshr,blr->bshl", q_abs.astype(c_kv.dtype), c_kv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,blk->bshl", chunk.q_pe, k_pe,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale                     # [B,S,H,L]
    if cfg.logit_softcap > 0.0:
        scores = jnp.tanh(scores / cfg.logit_softcap) * cfg.logit_softcap
    mask = kv_valid[:, None, :] & (kv_pos[:, None, :] <= q_pos[:, :, None])
    scores = jnp.where(mask[:, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * mask[:, :, None, :]  # all-masked rows -> exact zeros
    # context in latent space, then absorb W_uv on the way out
    ctx_lat = jnp.einsum("bshl,blr->bshr", probs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)  # [B,S,H,r]
    ctx = jnp.einsum("bshr,rhk->bshk", ctx_lat.astype(params["wv_b"].dtype),
                     params["wv_b"], preferred_element_type=jnp.float32)
    out = jnp.einsum("bshk,hkd->bsd", ctx.astype(chunk.q_nope.dtype),
                     params["wo"])
    return out


# ---------------------------------------------------------------------------
# Cross-attention (Whisper decoder -> encoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig, dtype):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, h, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, h, hd), d, dtype),
        "wo": _dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }


def apply_cross_attention(params, x: Array, enc_k: Array, enc_v: Array,
                          cfg: ModelConfig) -> Array:
    """x: [B, S, D]; enc_k/enc_v: [B, T, H, hd] precomputed from the encoder."""
    b, s, _ = x.shape
    t = enc_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    kv_pos = jnp.zeros((b, t), jnp.int32)  # non-causal: all visible
    valid = jnp.ones((b, t), bool)
    ctx = dot_attention(q, enc_k, enc_v, q_pos, kv_pos, valid, causal=False)
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


def encode_cross_kv(params, enc_out: Array):
    k = jnp.einsum("btd,dhk->bthk", enc_out, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, params["wv"])
    return k, v
