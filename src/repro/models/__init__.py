from repro.models.model import ForwardOutput, Model

__all__ = ["Model", "ForwardOutput"]
