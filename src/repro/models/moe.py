"""Mixture-of-Experts: top-k router + capacity-based dispatch (+shared experts).

Dispatch uses the GShard/MaxText "dropping" scheme: every token picks its
top-k experts, a cumulative-sum assigns it a slot within each expert's
fixed capacity buffer, overflow tokens are dropped (their combine weight is
zero, the residual path carries them).  The expert compute is one batched
einsum over a dense [E, Cap, D] buffer — TPU-friendly (static shapes, MXU
matmuls) and shardable: E over the expert-parallel axis, Cap over data.

DeepSeek-V2 additionally routes every token through ``num_shared_experts``
always-on experts (a plain dense MLP path here).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _dense_init


def _shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across JAX versions: the top-level binding (with
    check_vma) only exists from 0.5.x; 0.4.x ships it under
    jax.experimental with check_rep.  Both calls are fully-manual over
    every mesh axis, which is what the EP dispatch wants."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(mesh.axis_names),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)

Array = jnp.ndarray


def init_moe(key, cfg: ModelConfig, dtype):
    mo: MoEConfig = cfg.moe
    d, f = cfg.d_model, mo.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, mo.num_experts), d, jnp.float32),
        "w_gate_e": _dense_init(ks[1], (mo.num_experts, d, f), d, dtype),
        "w_up_e": _dense_init(ks[2], (mo.num_experts, d, f), d, dtype),
        "w_down_e": _dense_init(ks[3], (mo.num_experts, f, d), f, dtype),
    }
    if mo.num_shared_experts > 0:
        fs = mo.d_ff_shared * mo.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["w_gate_s"] = _dense_init(kk[0], (d, fs), d, dtype)
        p["w_up_s"] = _dense_init(kk[1], (d, fs), d, dtype)
        p["w_down_s"] = _dense_init(kk[2], (fs, d), fs, dtype)
    return p


def _capacity(tokens: int, mo: MoEConfig) -> int:
    cap = int(math.ceil(tokens * mo.top_k * mo.capacity_factor
                        / mo.num_experts))
    return max(8, int(math.ceil(cap / 8) * 8))  # pad for lane alignment


def apply_moe(params, x: Array, cfg: ModelConfig):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    from repro.distributed.sharding import current
    mo: MoEConfig = cfg.moe
    ctx = current()
    if mo.shard_map_ep and ctx is not None \
            and {"data", "model"} <= set(ctx.mesh.axis_names):
        msz = ctx.mesh.shape["model"]
        bsz = 1
        for a in ctx.mesh.axis_names:
            if a != "model":
                bsz *= ctx.mesh.shape[a]
        if (mo.num_experts % msz == 0 and x.shape[0] % bsz == 0
                and (x.shape[0] // bsz) * x.shape[1] % msz == 0):
            return apply_moe_shardmap(params, x, cfg, ctx.mesh)
    b, s, d = x.shape
    t = b * s
    e, k = mo.num_experts, mo.top_k
    xt = x.reshape(t, d)

    # ---- router (fp32 for stable softmax) --------------------------------
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    # load-balance aux loss: E * sum_e fraction_e * prob_e
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # [T, k, E]
    tokens_per_expert = jnp.sum(onehot, axis=(0, 1))           # [E]
    frac = tokens_per_expert / jnp.maximum(t * k, 1)
    mean_prob = jnp.mean(probs, axis=0)
    aux = mo.router_aux_weight * e * jnp.sum(frac * mean_prob)

    # ---- capacity slotting -------------------------------------------------
    # position of each (token, slot) within its expert: cumulative count
    # over the flattened [T*k] assignment stream via one-hot.
    # NOTE jnp.cumsum lowers to a quadratic reduce-window in XLA's cost
    # model; associative_scan is log-depth (§Perf it.1a: cut the MoE train
    # compute term 124x).  Attempts to localize the dispatch to data shards
    # with sharding constraints (it.1b/1d) all INCREASED collective traffic
    # 2-3x — GSPMD reshards the [E, Cap, *] buffers around the
    # scatter/einsum pair whatever the constraints say.  The real fix is
    # apply_moe_shardmap below (§Perf it.1e): explicit all-to-alls, 2.6x
    # lower collective traffic; this GSPMD path remains the fallback for
    # meshless execution and non-divisible shapes.
    cap = _capacity(t, mo)
    flat_onehot = onehot.reshape(t * k, e)
    csum = jax.lax.associative_scan(jnp.add, flat_onehot, axis=0)
    pos_in_expert = csum - flat_onehot
    pos = jnp.sum(pos_in_expert * flat_onehot, axis=-1).reshape(t, k)
    keep = pos < cap                                           # overflow drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # ---- dispatch: scatter tokens into [E, Cap, D] -------------------------
    pos_c = jnp.where(keep, pos, cap - 1).astype(jnp.int32)
    eidx = expert_idx.astype(jnp.int32)
    buf = jnp.zeros((e, cap, d), x.dtype)
    xk = jnp.broadcast_to(xt[:, None, :], (t, k, d))
    contrib = jnp.where(keep[..., None], xk, 0.0).reshape(t * k, d)
    buf = buf.at[eidx.reshape(-1), pos_c.reshape(-1)].add(
        contrib.astype(x.dtype), mode="drop")

    # ---- expert MLP (batched over E) ---------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate_e"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up_e"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down_e"])   # [E, Cap, D]

    # ---- combine: gather each token's k expert outputs ---------------------
    gathered = out[eidx.reshape(-1), pos_c.reshape(-1)].reshape(t, k, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), axis=1)

    # ---- shared experts (dense path, DeepSeek-V2) ---------------------------
    if mo.num_shared_experts > 0:
        sg = jnp.einsum("td,df->tf", xt, params["w_gate_s"])
        su = jnp.einsum("td,df->tf", xt, params["w_up_s"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                           params["w_down_s"])

    return y.reshape(b, s, d), aux


def apply_moe_reference(params, x: Array, cfg: ModelConfig):
    """O(E) dense oracle: every token through every expert, weighted by the
    (renormalized, non-capacity-dropped) top-k gates.  Used in tests to
    validate the dispatch path when nothing overflows."""
    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, mo.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->etf", xt, params["w_gate_e"])
    u = jnp.einsum("td,edf->etf", xt, params["w_up_e"])
    out = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, params["w_down_e"])
    mask = jax.nn.one_hot(expert_idx, mo.num_experts,
                          dtype=jnp.float32)          # [T, k, E]
    w = jnp.einsum("tke,tk->te", mask, gate_vals)     # [T, E]
    y = jnp.einsum("te,etd->td", w.astype(x.dtype), out)
    if mo.num_shared_experts > 0:
        sg = jnp.einsum("td,df->tf", xt, params["w_gate_s"])
        su = jnp.einsum("td,df->tf", xt, params["w_up_s"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                           params["w_down_s"])
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# shard_map expert parallelism (§Perf it.1e)
# ---------------------------------------------------------------------------

def _local_dispatch(xt, router, k, e, cap, aux_weight):
    """Shard-local routing + capacity dispatch.  xt: [Tl, D] (local tokens).
    Returns (buf [E, cap, D], eidx, pos_c, gate_vals, aux_partial)."""
    t, d = xt.shape
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)
    tokens_per_expert = jnp.sum(onehot, axis=(0, 1))
    frac = tokens_per_expert / jnp.maximum(t * k, 1)
    aux = aux_weight * e * jnp.sum(frac * jnp.mean(probs, axis=0))

    flat = onehot.reshape(t * k, e)
    csum = jax.lax.associative_scan(jnp.add, flat, axis=0)
    pos = jnp.sum((csum - flat) * flat, axis=-1).reshape(t, k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    pos_c = jnp.where(keep, pos, cap - 1).astype(jnp.int32)
    eidx = expert_idx.astype(jnp.int32)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    xk = jnp.broadcast_to(xt[:, None, :], (t, k, d))
    contrib = jnp.where(keep[..., None], xk, 0.0).reshape(t * k, d)
    buf = buf.at[eidx.reshape(-1), pos_c.reshape(-1)].add(
        contrib.astype(xt.dtype), mode="drop")
    return buf, eidx, pos_c, gate_vals, aux


def apply_moe_shardmap(params, x: Array, cfg: ModelConfig, mesh):
    """Expert-parallel MoE via jax.shard_map (manual over data+model):

      per device: local routing/dispatch (zero collectives) ->
      all_to_all(E -> expert-owning model shard) -> local expert MLP ->
      reverse all_to_all -> local combine.

    The only collectives are the two all-to-alls (point-to-point, ~Tl*k*D
    bytes) — replacing the GSPMD path's replicated-buffer all-gather +
    backward all-reduces (~3x that volume, and ~n x worse in per-link
    cost).  Requires E %% model_size == 0 and batch %% data_size == 0.
    """
    from jax.sharding import PartitionSpec as P

    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    e, k = mo.num_experts, mo.top_k
    msize = mesh.shape["model"]
    # batch shards over every non-model axis (data, and pod when present);
    # the body is FULLY manual over all mesh axes (partial-auto shard_map
    # trips an XLA-CPU AllReducePromotion crash on 3-axis meshes)
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    dsize = 1
    for a in batch_axes:
        dsize *= mesh.shape[a]
    t_local = (b // dsize) * s // msize   # tokens per (batch, model) shard
    cap = _capacity(t_local, mo)

    def body(xb, router, wg, wu, wd):
        # xb: [Bl, S, D] local, REPLICATED across the model axis.  Each
        # model shard dispatches only its 1/m token slice (otherwise all m
        # replicas redundantly dispatch the same tokens: measured 11x
        # compute blow-up before this slice was added).
        xt_all = xb.reshape(-1, d)
        # take this model shard's token slice.  psum_scatter of the
        # model-replicated array == slice (identical copies summed / m);
        # its transpose is a plain all-gather, which XLA's CPU backend
        # handles where the dynamic-slice transpose (bf16 all-reduce)
        # crashes its AllReducePromotion pass.
        xt = jax.lax.psum_scatter(xt_all.astype(jnp.float32), "model",
                                  scatter_dimension=0, tiled=True)
        xt = (xt / msize).astype(xt_all.dtype)
        tl = xt.shape[0]
        buf, eidx, pos_c, gates, aux = _local_dispatch(
            xt, router, k, e, cap, mo.router_aux_weight)
        # ship expert rows to their owners: [E, cap, D] -> [E/m, m*cap, D]
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        # bring each token's expert outputs home: [E/m, m*cap, D] -> [E, cap, D]
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)
        gathered = out[eidx.reshape(-1), pos_c.reshape(-1)] \
            .reshape(tl, k, d)
        y = jnp.sum(gathered * gates[..., None].astype(xt.dtype), axis=1)
        # reassemble the token dimension across model shards
        y = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(xb.shape), aux

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    y, aux = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, params["router"], params["w_gate_e"], params["w_up_e"],
      params["w_down_e"])

    if mo.num_shared_experts > 0:
        xt = x.reshape(-1, d)
        sg = jnp.einsum("td,df->tf", xt, params["w_gate_s"])
        su = jnp.einsum("td,df->tf", xt, params["w_up_s"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                           params["w_down_s"]).reshape(y.shape)
    return y, aux
