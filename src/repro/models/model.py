"""Unified model API: init / forward(train|prefill|decode) / init_cache.

One entry point for all 10 assigned architectures.  Frontends (Whisper's
mel+conv codec, InternVL's ViT) are stubs per the assignment: callers pass
precomputed ``audio_embeds`` / ``prefix_embeds`` of the right shape and the
model consumes them through a learned projection.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.attention import (apply_cross_attention, encode_cross_kv,
                                    init_cross_attention)
from repro.models.layers import (_dense_init, apply_embedding,
                                 apply_learned_pos, apply_norm,
                                 apply_unembed, init_embedding,
                                 init_learned_pos, init_norm, init_unembed,
                                 softcap)
from repro.models.transformer import (apply_stack, init_stack,
                                      init_stack_cache, stack_layout)

Array = jnp.ndarray


class ForwardOutput(NamedTuple):
    logits: Array          # [B, S, padded_vocab]
    cache: object          # stack cache (None in train mode)
    aux_loss: Array        # MoE load-balance scalar


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    def init(self, key: Array):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        params = {
            "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model,
                                    dtype),
            "stack": init_stack(ks[1], cfg, dtype),
            "final_norm": init_norm(ks[2], cfg.d_model, cfg.norm_type, dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_unembed(ks[3], cfg.d_model,
                                             cfg.padded_vocab, dtype)
        if cfg.pos_embedding == "learned":
            params["pos"] = init_learned_pos(ks[4], 32768, cfg.d_model, dtype)
        if cfg.frontend is not None:
            params["frontend_proj"] = _dense_init(
                ks[5], (cfg.d_model, cfg.d_model), cfg.d_model, dtype)
        if cfg.is_encdec:
            enc_cfg = self._encoder_cfg()
            params["encoder"] = {
                "stack": init_stack(ks[6], enc_cfg, dtype),
                "final_norm": init_norm(ks[7], cfg.d_model, cfg.norm_type,
                                        dtype),
            }
            # per-decoder-layer cross attention
            pattern, groups, rest = stack_layout(cfg)
            xkeys = jax.random.split(jax.random.fold_in(key, 99),
                                     len(pattern) + len(rest))
            xattn = {"scan": {}, "rest": {}}
            for i in range(len(pattern)):
                gk = jax.random.split(xkeys[i], groups)
                xattn["scan"][f"slot{i}"] = jax.vmap(
                    lambda k: {"xattn": init_cross_attention(k, cfg, dtype),
                               "norm_x": init_norm(k, cfg.d_model,
                                                   cfg.norm_type, dtype)})(gk)
            for j in range(len(rest)):
                k = xkeys[len(pattern) + j]
                xattn["rest"][f"layer{j}"] = {
                    "xattn": init_cross_attention(k, cfg, dtype),
                    "norm_x": init_norm(k, cfg.d_model, cfg.norm_type, dtype)}
            params["cross"] = xattn
        return params

    def _encoder_cfg(self) -> ModelConfig:
        cfg = self.cfg
        return dataclasses.replace(
            cfg, num_layers=cfg.encoder.num_layers, block_pattern=("attn",),
            moe=None, mla=None, encoder=None, window=0,
            pos_embedding="learned")

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=None,
                   ring_headroom: int = 0, paged: bool = False,
                   block_size: int = 16, num_blocks: int = 0):
        """ring_headroom: extra ring slots for chunked decode — see
        ``init_block_cache``; pass chunk_len - 1 when decoding S-token
        chunks against sliding-window layers.

        paged: full-attention layers use block-pool caches (shared pool +
        per-row block table; docs/KV_CACHE.md) so serving admission can
        free/reuse blocks per row instead of re-prefilling whole rows."""
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return init_stack_cache(self.cfg, batch, cache_len, dtype,
                                ring_headroom, paged, block_size,
                                num_blocks)

    # ------------------------------------------------------------------
    def encode(self, params, audio_embeds: Array) -> Array:
        """Encoder pass over stubbed frontend embeddings [B, T, D]."""
        cfg = self.cfg
        enc_cfg = self._encoder_cfg()
        x = audio_embeds.astype(jnp.dtype(cfg.dtype))
        x = jnp.einsum("btd,de->bte", x, params["frontend_proj"]) \
            if "frontend_proj" in params else x
        b, t, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        if "pos" in params:
            x = apply_learned_pos(params["pos"], x, pos)
        x, _, _ = apply_stack(params["encoder"]["stack"], enc_cfg, x, pos,
                              None, "train", causal=False)
        return apply_norm(params["encoder"]["final_norm"], x, cfg.norm_type)

    # ------------------------------------------------------------------
    def encode_cross(self, params, enc_out: Array):
        """Precompute per-decoder-layer cross-attention K/V from the encoder
        output (serving: computed once at prefill; §Perf it.3)."""
        cross = params["cross"]
        out = {"scan": {}, "rest": {}}
        for slot, p in cross["scan"].items():
            out["scan"][slot] = jax.vmap(
                lambda pp: encode_cross_kv(pp["xattn"], enc_out))(p)
        for name, p in cross["rest"].items():
            out["rest"][name] = encode_cross_kv(p["xattn"], enc_out)
        return out

    def forward(self, params, tokens: Array, *, mode: str = "train",
                cache=None, positions: Optional[Array] = None,
                chunk_valid: Optional[Array] = None,
                prefix_embeds: Optional[Array] = None,
                enc_out: Optional[Array] = None,
                cross_kv=None,
                remat: bool = False,
                shared_blocks: Optional[Array] = None,
                shared_lens: Optional[Array] = None) -> ForwardOutput:
        """tokens: i32[B, S].  mode: train | prefill | decode.

        prefix_embeds: [B, P, D] VLM patch embeddings, prepended (train and
        prefill only).  enc_out: [B, T, D] encoder output for enc-dec models.
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = apply_embedding(params["embed"], tokens).astype(dtype)

        if prefix_embeds is not None:
            assert mode in ("train", "prefill")
            pe = prefix_embeds.astype(dtype)
            if "frontend_proj" in params:
                pe = jnp.einsum("bpd,de->bpe", pe, params["frontend_proj"])
            x = jnp.concatenate([pe, x], axis=1)
            s = x.shape[1]
            if chunk_valid is not None:
                pv = jnp.ones((b, prefix_embeds.shape[1]), bool)
                chunk_valid = jnp.concatenate([pv, chunk_valid], axis=1)

        if positions is None:
            assert mode in ("train", "prefill"), \
                "decode mode requires explicit positions"
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        elif prefix_embeds is not None:
            p = prefix_embeds.shape[1]
            positions = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(p)[None, :], (b, p)),
                 positions + p], axis=1)

        if "pos" in params and cfg.pos_embedding == "learned":
            x = apply_learned_pos(params["pos"], x, positions)

        x = constrain(x, "batch", "seq", "embed")
        cross = params.get("cross")
        x, cache, aux = apply_stack(
            params["stack"], cfg, x, positions, cache, mode,
            chunk_valid=chunk_valid, remat=remat, enc_out=enc_out,
            cross_params=cross, cross_kv=cross_kv,
            shared_blocks=shared_blocks, shared_lens=shared_lens)
        x = apply_norm(params["final_norm"], x, cfg.norm_type)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["embedding"])
        else:
            logits = apply_unembed(params["unembed"], x)
        logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
        logits = constrain(logits, "batch", "seq", "vocab")
        return ForwardOutput(logits=logits, cache=cache, aux_loss=aux)
