"""Logical-axis sharding rules (MaxText-style) for params and activations.

Model code annotates tensors with *logical* axis names; a ``ShardingContext``
maps logical names to mesh axes, dropping any assignment whose dimension is
not divisible by the mesh axis size (e.g. whisper's 8 heads on a 16-way
model axis fall back to replicated).  With no active context every
annotation is a no-op, so single-device tests never touch device state.

Two built-in rule sets:
  * ``TRAIN_RULES`` — batch over (pod, data); tensor parallel over model;
    FSDP: large param matrices additionally shard their d_model axis over
    data (ZeRO-3-style; GSPMD inserts the per-layer all-gathers).
  * ``SERVE_RULES`` — batch over data; tensor parallel over model; KV-cache
    length sequence-sharded over model (flash-decode); experts over data.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jnp.ndarray

_state = threading.local()

# logical axis -> mesh axis (or tuple of mesh axes)
TRAIN_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "model",   # Megatron-SP style: carry activations sharded on d
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": "data",
    "vocab": "model",
    "fsdp": "data",        # param d_model axis, ZeRO-style
    "cache_len": None,
    "latent": None,
    "moe_e": None,         # dispatch-buffer expert axis (scatter-indexed)
}

SERVE_RULES = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "data",
    "expert_cap": None,
    "vocab": "model",
    "fsdp": None,          # params replicated over data for serving
    "cache_len": "model",  # sequence-sharded KV (flash-decode)
    "latent": None,
    "moe_e": None,
}


class ShardingContext:
    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = dict(rules)
        # drop the pod axis from rules when the mesh doesn't have one
        if "pod" not in mesh.axis_names:
            for k, v in self.rules.items():
                if isinstance(v, tuple):
                    v = tuple(a for a in v if a in mesh.axis_names)
                    self.rules[k] = v[0] if len(v) == 1 else (v or None)
                elif v not in mesh.axis_names:
                    self.rules[k] = None

    def axis_size(self, mesh_axis) -> int:
        if mesh_axis is None:
            return 1
        if isinstance(mesh_axis, tuple):
            out = 1
            for a in mesh_axis:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[mesh_axis]

    def spec(self, logical_axes: tuple, shape: tuple | None = None) -> P:
        """PartitionSpec for the given logical axes; divisibility-guarded
        when a concrete shape is supplied."""
        entries = []
        used = set()
        for i, name in enumerate(logical_axes):
            mesh_axis = self.rules.get(name) if name else None
            if mesh_axis is None:
                entries.append(None)
                continue
            axes = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                entries.append(None)
                continue
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            if shape is not None and shape[i] % size != 0:
                entries.append(None)  # not divisible -> replicate
                continue
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, logical_axes: tuple, shape: tuple | None = None):
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def current() -> ShardingContext | None:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: dict):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ShardingContext(mesh, rules)
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def constrain(x: Array, *logical_axes) -> Array:
    """with_sharding_constraint by logical axes; no-op without a context."""
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(tuple(logical_axes), x.shape))


# ---------------------------------------------------------------------------
# Param / cache / batch sharding-spec derivation (by leaf name)
# ---------------------------------------------------------------------------

# logical axes per param leaf name (without any scan-stacking axis)
PARAM_AXES = {
    "embedding": ("vocab", "fsdp"),
    "pos_embedding": (None, None),
    "w_unembed": ("fsdp", "vocab"),
    "wq": ("fsdp", "heads", None),
    "wk": ("fsdp", "kv_heads", None),
    "wv": ("fsdp", "kv_heads", None),
    "wo": ("heads", None, "fsdp"),
    "w_gate": ("fsdp", "mlp"),
    "w_up": ("fsdp", "mlp"),
    "w_down": ("mlp", "fsdp"),
    "w_gate_s": ("fsdp", "mlp"),
    "w_up_s": ("fsdp", "mlp"),
    "w_down_s": ("mlp", "fsdp"),
    "router": ("fsdp", None),
    "w_gate_e": ("experts", "fsdp", None),
    "w_up_e": ("experts", "fsdp", None),
    "w_down_e": ("experts", None, "fsdp"),
    # MLA
    "wq_a": ("fsdp", None),
    "wq_b": (None, "heads", None),
    "wkv_a": ("fsdp", None),
    "wk_b": (None, "heads", None),
    "wv_b": (None, "heads", None),
    # mLSTM
    "w_in": ("fsdp", "mlp"),
    "w_z": ("fsdp", "mlp"),
    "wq_m": ("mlp", None, None),
    "wk_m": ("mlp", None, None),
    "wv_m": ("mlp", None, None),
    "w_if": ("mlp", None, None),
    "b_if": (None, None),
    "w_out": ("mlp", "fsdp"),
    # sLSTM
    "w_zi": ("fsdp", None), "w_ii": ("fsdp", None), "w_fi": ("fsdp", None),
    "w_oi": ("fsdp", None),
    "r_z": ("fsdp", None), "r_i": ("fsdp", None), "r_f": ("fsdp", None),
    "r_o": ("fsdp", None),
    "b_f": (None,),
    # RG-LRU
    "w_x": ("fsdp", "mlp"),
    "conv_w": (None, "mlp"),
    "w_a": ("mlp", None),
    "w_i": ("mlp", None),
    "lambda_param": ("mlp",),
    # misc
    "frontend_proj": ("fsdp", None),
    "scale": (None,),
    "bias": (None,),
}

# cache/state leaves by NamedTuple field name
CACHE_AXES = {
    "k": ("batch", "cache_len", "kv_heads", None),
    "v": ("batch", "cache_len", "kv_heads", None),
    "ckv": ("batch", "cache_len", None),
    "kpe": ("batch", "cache_len", None),
    "pos_arr": ("batch", "cache_len"),
    "next_pos": ("batch",),
    "C": ("batch", None, None, None),
    "n": ("batch", None, None),        # mLSTM normalizer [B,H,dk]
    "m": ("batch", None),
    "conv": ("batch", None, "mlp"),
    "h": ("batch", "mlp"),
    "c": ("batch", None),
}

BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "positions": ("batch", None),
    "prefix_embeds": ("batch", None, None),
    "audio_embeds": ("batch", None, None),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        key = getattr(p, "key", None)
        if isinstance(key, str):
            return key
        name = getattr(p, "name", None)  # NamedTuple fields
        if isinstance(name, str):
            return name
    return ""


def _axes_for(path, ndim, table):
    name = _leaf_name(path)
    axes = table.get(name)
    if axes is None:
        # sLSTM state fields share names with mLSTM (c/n/h/m) — ndim fixes it
        if name == "n" and ndim - 1 <= 2:
            axes = ("batch", None)
        else:
            axes = (None,) * ndim
    if len(axes) < ndim:  # scan stacking prepends a layers axis
        axes = (None,) * (ndim - len(axes)) + tuple(axes)
    return tuple(axes[:ndim])


def tree_specs(ctx: ShardingContext, tree, table=None):
    """PartitionSpec pytree for a params/cache/batch pytree (or its
    eval_shape shadow), matching leaves by name with divisibility guards."""
    table = table or PARAM_AXES

    def spec_leaf(path, leaf):
        shape = tuple(leaf.shape)
        return ctx.spec(_axes_for(path, len(shape), table), shape)

    return jax.tree_util.tree_map_with_path(spec_leaf, tree)


def tree_shardings(ctx: ShardingContext, tree, table=None):
    specs = tree_specs(ctx, tree, table)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
