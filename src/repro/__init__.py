"""GoodSpeed: fair-goodput adaptive speculative decoding (JAX, TPU-native).

Reproduction + production framework for Tran et al., CS.DC 2025.
See README.md for the public API tour.
"""

__version__ = "1.0.0"
