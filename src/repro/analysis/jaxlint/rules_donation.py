"""JL001 — donation-after-use.

The engine's jit entry points donate their state/cache operands
(``donate_argnums``): after the dispatch, the argument's buffers are
DELETED and only the returned value is alive (engine.py: "state is
donated to the compiled phases — use the returned state, not the
argument").  Reading the donated binding afterwards either crashes with
a deleted-buffer error on device or, worse, silently disables donation
and doubles peak memory.

The checker walks each function's statements in order with a small
abstract environment of donated dotted paths:

  * a call to a donating binding (``self._round_fn``, ``run_round``, a
    ``jax.jit(..., donate_argnums=...)`` result — see
    ``ModuleModel.donators``, which includes the transitive closure)
    marks the argument at each donated position, when it is a plain
    ``name`` or dotted ``name.attr`` path;
  * any later read of that path (or a sub-path of it) is a finding;
  * rebinding the name (``state, stats = self.run_round(state, ...)``)
    clears it — the donate-and-rebind idiom is the sanctioned pattern;
  * ``if``/``else`` branches analyze independently and merge; a branch
    that TERMINATES (return/raise/break/continue) contributes nothing
    to the fall-through state, so the early-return dispatch idiom
    (``if sync: return self._round_fn(state, ...)`` followed by
    overlap-phase reads of ``state``) is clean; loop bodies run twice
    so a donation at the bottom of a round loop flags the read at the
    top of the next iteration.
"""
from __future__ import annotations

import ast

from repro.analysis.jaxlint.core import Finding
from repro.analysis.jaxlint.model import ModuleModel, dotted_path

CODE = "JL001"


def _load_paths(expr):
    """Maximal dotted paths read (Load context) inside ``expr``."""
    out = []

    def visit(node):
        p = dotted_path(node)
        if p is not None and isinstance(node, (ast.Name, ast.Attribute)):
            ctx = getattr(node, "ctx", None)
            if isinstance(ctx, ast.Load):
                out.append((p, node))
                return                      # maximal path: stop descending
        for child in ast.iter_child_nodes(node):
            visit(child)

    if expr is not None:
        visit(expr)
    return out


def _kill(donated: dict, path: str):
    """Rebinding ``path`` clears every donated entry rooted at it."""
    for k in list(donated):
        if k == path or k.startswith(path + "."):
            del donated[k]


def _stmt_exprs(st):
    """The expressions a statement evaluates at its own level (compound
    bodies are recursed into separately)."""
    if isinstance(st, (ast.If, ast.While)):
        return [st.test]
    if isinstance(st, ast.For):
        return [st.iter]
    if isinstance(st, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in st.items]
    if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    return [st]


def _terminates(stmts) -> bool:
    """Does this block unconditionally leave the enclosing code path?
    (Its donation state then never reaches the statements after the
    ``if``.)"""
    for st in stmts:
        if isinstance(st, (ast.Return, ast.Raise, ast.Break, ast.Continue)):
            return True
        if isinstance(st, ast.If) and st.orelse \
                and _terminates(st.body) and _terminates(st.orelse):
            return True
    return False


def _kills(st):
    """Paths rebound by this statement (assignment/for targets)."""
    targets = []
    if isinstance(st, ast.Assign):
        targets = st.targets
    elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
        targets = [st.target]
    elif isinstance(st, ast.For):
        targets = [st.target]
    elif isinstance(st, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in st.items if i.optional_vars]
    out = []
    for t in targets:
        for node in ast.walk(t):
            p = dotted_path(node)
            if p is not None and isinstance(node, (ast.Name, ast.Attribute)):
                out.append(p)
    return out


class _FnChecker:
    def __init__(self, model: ModuleModel, fn):
        self.model = model
        self.fn = fn
        self.findings: dict = {}            # dedup key -> Finding

    def run(self):
        body = getattr(self.fn.node, "body", [])
        self._block(body, {})
        return list(self.findings.values())

    # -- statement walk ------------------------------------------------
    def _block(self, stmts, donated: dict):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue                     # separate scope
            for expr in _stmt_exprs(st):
                self._flag_reads(expr, donated)
                self._record_donations(expr, donated)
            for path in _kills(st):
                _kill(donated, path)
            if isinstance(st, ast.If):
                d_then, d_else = dict(donated), dict(donated)
                self._block(st.body, d_then)
                self._block(st.orelse, d_else)
                donated.clear()
                if not _terminates(st.body):
                    donated.update(d_then)
                if not _terminates(st.orelse):
                    donated.update(d_else)
            elif isinstance(st, (ast.For, ast.While)):
                d_loop = dict(donated)
                for _ in range(2):           # 2nd pass: wraparound reads
                    self._block(st.body, d_loop)
                    for expr in _stmt_exprs(st):
                        self._flag_reads(expr, d_loop)
                        self._record_donations(expr, d_loop)
                self._block(st.orelse, d_loop)
                donated.update(d_loop)       # union: loop may run 0 times
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                self._block(st.body, donated)
            elif isinstance(st, ast.Try):
                self._block(st.body, donated)
                for h in st.handlers:
                    self._block(h.body, donated)
                self._block(st.orelse, donated)
                self._block(st.finalbody, donated)

    # -- reads / donations ---------------------------------------------
    def _flag_reads(self, expr, donated: dict):
        if not donated:
            return
        for path, node in _load_paths(expr):
            for dpath, (dline, dcallee) in donated.items():
                if path == dpath or path.startswith(dpath + "."):
                    key = (node.lineno, node.col_offset, path)
                    self.findings.setdefault(key, Finding(
                        code=CODE, path=self.model.path,
                        line=node.lineno, col=node.col_offset,
                        message=(f"`{path}` is read after being donated "
                                 f"to `{dcallee}` (line {dline}); its "
                                 f"buffers are deleted — use the "
                                 f"returned value instead")))

    def _record_donations(self, expr, donated: dict):
        if expr is None:
            return
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            key = self.model._donation_key(node)
            if key is None or key not in self.model.donators:
                continue
            for pos in self.model.donators[key]:
                if pos < len(node.args):
                    p = dotted_path(node.args[pos])
                    if p is not None:
                        donated[p] = (node.lineno, key)


def check(model: ModuleModel):
    findings = []
    for fn in model.functions:
        findings.extend(_FnChecker(model, fn).run())
    return findings
