"""JL002 / JL003 / JL004 — retrace hazards.

A GoodSpeed serving run must never retrace a round phase more than once
per bucket (engine.py's ``round_trace_counts`` contract): a retrace in
the round loop stalls every server for a full XLA compile.  Three ways
code acquires that hazard, three rules:

JL002  jit-in-hot-scope.  ``jax.jit`` (or ``functools.partial(jax.jit,
       ...)``) evaluated inside an ordinary function creates a FRESH
       compilation cache per call — in a per-round function that is a
       guaranteed retrace.  Allowed scopes: module/class level and
       construction-time scopes (``__init__`` / ``__post_init__`` /
       ``__new__`` / ``__init_subclass__``), including factories nested
       inside them (the engine's ``_make_prefill`` idiom).  A
       launch-time jit in a run-once entry point is legitimate —
       suppress it with a justification comment.

JL003  unhashable-static-arg.  A dict/list/set literal passed in a jit
       static position (``static_argnums`` / ``static_argnames``)
       either raises ``unhashable type`` or — wrapped in a custom
       hashable — silently keys the compilation cache on identity,
       retracing every call.

JL004  traced-python-branch.  ``if`` / ``while`` / ``assert`` (or a
       conditional expression) whose test reads a TRACED value inside
       the jit call tree: under trace this raises
       ``ConcretizationTypeError`` at best, and when the value is
       accidentally concrete (e.g. a host fallback path) it silently
       bakes the branch into the compiled graph — a per-value retrace
       or a wrong graph.  ``x is None`` / ``x is not None`` tests are
       exempt (structure checks, resolved at trace time), as are
       parameters named in ``static_argnames`` and reads of static
       metadata (``.shape`` / ``.ndim`` / ``len()``).
"""
from __future__ import annotations

import ast

from repro.analysis.jaxlint.core import Finding
from repro.analysis.jaxlint.model import (INIT_SCOPES, ModuleModel,
                                          dotted_path, is_jax_jit, jit_call,
                                          jit_options)

MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp, ast.GeneratorExp)


def _scope_allowed(chain) -> bool:
    """A jit creation is fine at module/class level or anywhere lexically
    inside a construction-time scope."""
    return not chain or any(name in INIT_SCOPES for name in chain)


def check_jit_scope(model: ModuleModel):
    """JL002: jax.jit evaluated in a per-call scope."""
    findings = []
    # decorators execute in the scope ENCLOSING the decorated def
    decorator_nodes = set()
    for fn in model.functions:
        for dec in fn.node.decorator_list:
            for sub in ast.walk(dec):
                decorator_nodes.add(id(sub))
            if is_jax_jit(dec) or jit_call(dec) is not None:
                if not _scope_allowed(fn.lexical_chain):
                    findings.append(Finding(
                        code="JL002", path=model.path, line=dec.lineno,
                        col=dec.col_offset,
                        message=(f"jit decorator on `{fn.name}` is "
                                 f"evaluated inside "
                                 f"`{fn.lexical_chain[-1]}` — a fresh "
                                 f"compile cache per call; build the jit "
                                 f"once at module or construction time")))
    for node in ast.walk(model.tree):
        call = jit_call(node)
        if call is not node or id(node) in decorator_nodes:
            continue
        owner = model.owner(node)
        if owner is None:
            continue                         # module/class level: allowed
        chain = owner.lexical_chain + (owner.name,)
        if _scope_allowed(chain):
            continue
        findings.append(Finding(
            code="JL002", path=model.path, line=node.lineno,
            col=node.col_offset,
            message=(f"jax.jit created inside `{owner.name}` — a fresh "
                     f"compile cache per call (retrace hazard in any "
                     f"per-round path); build the jit once at module or "
                     f"construction time, or suppress with a "
                     f"justification if this provably runs once")))
    return findings


def _static_bindings(model: ModuleModel) -> dict:
    """Call-site binding name -> (static positional indices, static
    keyword names)."""
    bindings: dict[str, tuple] = {}

    def from_call(call):
        opts = jit_options(call)
        nums = tuple(i for i in opts["static_argnums"]
                     if isinstance(i, int))
        names = tuple(a for a in opts["static_argnames"]
                      if isinstance(a, str))
        return (nums, names) if (nums or names) else None

    for node in ast.walk(model.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            call = jit_call(node.value)
            if call is not None and is_jax_jit(call.func):
                st = from_call(call)
                tgt = dotted_path(node.targets[0])
                if st and tgt:
                    bindings[tgt.split(".")[-1]] = st
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "__setattr__" and len(node.args) == 3:
            call = jit_call(node.args[2])
            if call is not None and is_jax_jit(call.func):
                st = from_call(call)
                if st and isinstance(node.args[1], ast.Constant) and \
                        isinstance(node.args[1].value, str):
                    bindings[node.args[1].value] = st
    for fn in model.functions:
        if fn.jit_root and (fn.static_nums or fn.static_names):
            bindings.setdefault(
                fn.name, (fn.static_nums, tuple(fn.static_names)))
    return bindings


def check_static_args(model: ModuleModel):
    """JL003: unhashable literals in jit static positions."""
    findings = []
    bindings = _static_bindings(model)

    def flag(node, key, what):
        findings.append(Finding(
            code="JL003", path=model.path, line=node.lineno,
            col=node.col_offset,
            message=(f"unhashable {what} passed as a static argument of "
                     f"jit-compiled `{key}` — static args key the "
                     f"compile cache and must be hashable (use a tuple "
                     f"/ frozenset / frozen dataclass)")))

    kind = {ast.List: "list literal", ast.Dict: "dict literal",
            ast.Set: "set literal", ast.ListComp: "list comprehension",
            ast.DictComp: "dict comprehension",
            ast.SetComp: "set comprehension",
            ast.GeneratorExp: "generator expression"}
    for node in ast.walk(model.tree):
        if not isinstance(node, ast.Call):
            continue
        key = model._donation_key(node)
        if key is None or key not in bindings:
            continue
        nums, names = bindings[key]
        for i in nums:
            if i < len(node.args) and isinstance(node.args[i],
                                                 MUTABLE_LITERALS):
                flag(node.args[i], key, kind[type(node.args[i])])
        for kw in node.keywords:
            if kw.arg in names and isinstance(kw.value, MUTABLE_LITERALS):
                flag(kw.value, key, kind[type(kw.value)])
    # def-site: a static parameter with a mutable default
    for fn in model.functions:
        if not fn.jit_root:
            continue
        for p, default in fn.default_nodes.items():
            if p in fn.static_names and isinstance(default,
                                                   MUTABLE_LITERALS):
                findings.append(Finding(
                    code="JL003", path=model.path, line=default.lineno,
                    col=default.col_offset,
                    message=(f"static parameter `{p}` of jit-compiled "
                             f"`{fn.name}` has an unhashable default")))
    return findings


def _prune_is_none(test):
    """Subexpressions of a test that still need the traced-value check:
    ``x is None`` / ``x is not None`` comparisons and ``"key" in x``
    membership tests (pytree STRUCTURE — dict keys, not array values)
    are resolved at trace time and drop out entirely."""
    if isinstance(test, ast.Compare) and \
            all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return []
    if isinstance(test, ast.Compare) and \
            all(isinstance(op, (ast.In, ast.NotIn)) for op in test.ops) \
            and isinstance(test.left, ast.Constant) \
            and isinstance(test.left.value, str):
        return []
    if isinstance(test, ast.BoolOp):
        out = []
        for v in test.values:
            out.extend(_prune_is_none(v))
        return out
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _prune_is_none(test.operand)
    return [test]


def check_traced_branch(model: ModuleModel):
    """JL004: Python control flow on a traced value in the jit tree."""
    findings = []
    for fn in model.functions:
        if not model.is_hot(fn):
            continue
        traced = model.traced_names(fn)
        if not traced:
            continue
        for node in model.iter_function_nodes(fn):
            if isinstance(node, (ast.If, ast.While, ast.Assert, ast.IfExp)):
                test = node.test
                stmt = {ast.If: "if", ast.While: "while",
                        ast.Assert: "assert",
                        ast.IfExp: "conditional expression"}[type(node)]
            else:
                continue
            for sub in _prune_is_none(test):
                name = model.mentions_traced(sub, traced)
                if name:
                    findings.append(Finding(
                        code="JL004", path=model.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"`{stmt}` on traced value `{name}` "
                                 f"inside the jit call tree of "
                                 f"`{fn.name}` — trace-time Python "
                                 f"branching on device data; use "
                                 f"jnp.where / lax.cond / lax.select")))
                    break
    return findings


def check(model: ModuleModel):
    return (check_jit_scope(model) + check_static_args(model)
            + check_traced_branch(model))
