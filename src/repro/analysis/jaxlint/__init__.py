"""jaxlint — repo-native static analysis for jit discipline.

The GoodSpeed serving round only stays fast (and correct) while three
invariants hold: donated buffers are never read after the dispatch that
consumed them, the round graph never retraces in steady state, and no
host sync sneaks into the jit-traced call tree.  Docstrings state these
rules; jaxlint enforces them over ``src/`` as a tier-1 test and CI gate
(``make lint-check``).

Rule families (see docs/STATIC_ANALYSIS.md for the full table):

  JL001  donation-after-use     read of a binding after it was passed in
                                a ``donate_argnums`` position
  JL002  jit-in-hot-scope       ``jax.jit`` created inside a per-round
                                function or loop (retrace hazard)
  JL003  unhashable-static-arg  dict/list/set literal passed in a jit
                                static position (retrace hazard)
  JL004  traced-python-branch   ``if``/``while``/``assert`` on a traced
                                value inside the jit call tree
  JL005  host-sync-in-jit       ``.item()``, ``int()/float()/bool()``,
                                ``np.asarray``, f-string interpolation
                                of a traced value inside the jit call
                                tree
  JL006  sticky-flag-overwrite  in-graph sticky error flags
                                (``alloc_failed``/``overflowed``)
                                plainly assigned instead of accumulated

Suppression: append ``# jaxlint: disable=JLxxx`` (comma-separate several
codes) on the flagged line or the line directly above it, with a comment
saying why.

Run: ``python -m repro.analysis.jaxlint src`` (or ``make lint-check``).
"""
from repro.analysis.jaxlint.core import (Finding, RULES, lint_file,
                                         lint_paths, lint_source)

__all__ = ["Finding", "RULES", "lint_file", "lint_paths", "lint_source"]
