"""JL006 — sticky-flag discipline.

The paged KV caches carry in-graph error flags — the pool-exhaustion
scalar ``alloc_failed`` and the per-row capacity flag ``overflowed`` —
that are STICKY by contract (docs/KV_CACHE.md): once a write is dropped
the flag must survive every later cache operation until the host reads
it (``_check_pool_health``) or a sanctioned reset clears it.  A plain
assignment (``_replace(alloc_failed=this_write_failed)``) silently
un-sets an earlier round's failure and the serving loop keeps decoding
on a cache that is missing K/V.

The rule: every write to a sticky flag — a ``_replace(alloc_failed=…)``
/ ``dataclasses.replace(x, overflowed=…)`` keyword or a plain attribute
assignment — must derive from the PREVIOUS flag value:

  * OK: ``cache._replace(alloc_failed=cache.alloc_failed | failed)``
    (accumulation), directly or through local names whose defining
    expression reads a sticky flag (fori_loop carries included);
  * OK: explicit initialization to ``None`` / ``False`` /
    ``jnp.zeros(...)`` — fresh-cache constructors and sanctioned row
    resets (``jnp.where(rows, False, cache.overflowed)`` reads the old
    flag and therefore also passes as accumulation-shaped);
  * OK: ``x.overflowed |= ...`` augmented assignment;
  * FLAGGED: any other assignment — the write is not provably monotone.

Constructor calls (``PagedAttnCache(...)``, ``StickyFlags(...)``) build
NEW objects and are exempt; the rule targets updates of an existing
cache.  A deliberate non-monotone restore (snapshot/rollback) should
name the restored value after the flag — the engine's ``discard_tail``
restore passes because its parameters are literally ``alloc_failed`` /
``overflowed`` — or carry a ``# jaxlint: disable=JL006`` justification.
"""
from __future__ import annotations

import ast

from repro.analysis.jaxlint.core import Finding
from repro.analysis.jaxlint.model import ModuleModel, dotted_path

CODE = "JL006"
STICKY = {"alloc_failed", "overflowed"}
ZERO_CALLS = {"jnp.zeros", "jnp.zeros_like", "np.zeros", "jnp.full",
              "jnp.broadcast_to"}


def _reads_sticky(expr, derived: set) -> bool:
    """Does ``expr`` read a sticky flag — an ``.alloc_failed`` /
    ``.overflowed`` attribute, a bare name matching a flag, or a local
    name derived from one?"""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in STICKY:
            return True
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and (node.id in STICKY or node.id in derived):
            return True
    return False


def _allowed(expr, derived: set) -> bool:
    if isinstance(expr, ast.Constant) and expr.value in (None, False):
        return True
    if isinstance(expr, ast.Call):
        path = dotted_path(expr.func)
        if path in ZERO_CALLS:
            # jnp.zeros(cache.overflowed.shape) style inits are resets
            # by construction; a zeros-of-shape also reads the old flag
            return True
    return _reads_sticky(expr, derived)


def _derived_names(model: ModuleModel, fn) -> set:
    """Local names whose defining statement reads a sticky flag,
    transitively (covers ``failed = cache.alloc_failed | ...`` and
    fori_loop carry unpacks seeded with the flag)."""
    derived: set = set()
    nodes = list(model.iter_function_nodes(fn)) if fn is not None \
        else [n for n in ast.walk(model.tree) if model.owner(n) is None]
    assigns = [n for n in nodes
               if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign))]
    for p in fn.params if fn is not None else ():
        if p in STICKY:
            derived.add(p)
    for _ in range(len(assigns) + 1):
        grew = False
        for node in assigns:
            if node.value is None or not _reads_sticky(node.value, derived):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name) and leaf.id not in derived:
                        derived.add(leaf.id)
                        grew = True
        if not grew:
            break
    return derived


def check(model: ModuleModel):
    findings = []

    def flag(node, what, name):
        findings.append(Finding(
            code=CODE, path=model.path, line=node.lineno,
            col=node.col_offset,
            message=(f"sticky flag `{name}` is plainly assigned ({what}) "
                     f"— sticky flags must accumulate from their "
                     f"previous value (`old | new`, logical_or); a "
                     f"deliberate snapshot restore needs a "
                     f"`# jaxlint: disable=JL006` justification")))

    scopes = [None] + list(model.functions)
    for fn in scopes:
        derived = _derived_names(model, fn)
        nodes = list(model.iter_function_nodes(fn)) if fn is not None \
            else [n for n in ast.walk(model.tree) if model.owner(n) is None]
        for node in nodes:
            if isinstance(node, ast.Call):
                f = node.func
                is_replace = (isinstance(f, ast.Attribute)
                              and f.attr == "_replace")
                path = dotted_path(f)
                is_dc_replace = path in ("dataclasses.replace", "replace")
                if not (is_replace or is_dc_replace):
                    continue
                for kw in node.keywords:
                    if kw.arg in STICKY and not _allowed(kw.value, derived):
                        flag(kw.value, "_replace keyword", kw.arg)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and t.attr in STICKY \
                            and not _allowed(node.value, derived):
                        flag(node, "attribute assignment", t.attr)
            elif isinstance(node, ast.AugAssign):
                if isinstance(node.target, ast.Attribute) and \
                        node.target.attr in STICKY and \
                        not isinstance(node.op, ast.BitOr):
                    flag(node, "augmented assignment", node.target.attr)
    return findings
