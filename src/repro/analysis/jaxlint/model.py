"""Per-module semantic model backing the jaxlint rules.

One ``ModuleModel`` is built per linted file (pure ``ast``, no imports of
the linted code).  It answers the questions every rule family needs:

  * which functions are JIT ROOTS — decorated ``@jax.jit`` /
    ``@functools.partial(jax.jit, ...)``, or passed to a ``jax.jit(...)``
    call anywhere in the module (the engine's
    ``object.__setattr__(self, "_round_fn", jax.jit(self._round_core,
    donate_argnums=(0,)))`` idiom resolves the method by name);
  * which functions are JIT-REACHABLE — the same-module call-graph
    closure over the roots, following ``f(...)``, ``self.f(...)`` and
    bare-name function arguments (closures handed to ``jax.lax.scan`` /
    ``jax.tree.map`` run in-graph too).  Cross-module reachability is
    deliberately out of scope: each module is linted against its own
    roots, so in-graph helper modules get their own roots or stay
    host-annotated;
  * which call-site bindings DONATE which argument positions — direct
    ``jax.jit(..., donate_argnums=...)`` bindings, factory functions
    returning such a jit, and the TRANSITIVE closure (a function that
    forwards its own parameter into a donated position donates that
    parameter to its callers, which is how ``run_round``'s donation of
    ``state`` is discovered from ``_round_fn``'s);
  * which local names hold TRACED values inside a function — parameters
    annotated with an array type (``Array`` / ``jnp.ndarray`` /
    ``jax.Array``), every non-static parameter of a jit ROOT, and names
    assigned from ``jnp.* / jax.lax.* / jax.random.*`` expressions or
    from other traced names.  Reads of static metadata
    (``.shape/.ndim/.dtype/.size``, ``len()``) do not propagate
    tracedness.

Suppressions: ``# jaxlint: disable=JL001[,JL002...]`` on the finding's
line or the line directly above suppresses those codes (``all`` matches
every code).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Optional

# parameter annotations treated as "this is a traced array"
ARRAY_ANNOTATIONS = {"Array", "ndarray", "jnp.ndarray", "jax.Array",
                     "jnp.array", "chex.Array"}
# attribute reads that yield static (trace-time) metadata, not traced data
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "weak_type"}
# call roots whose results are traced arrays
TRACED_CALL_ROOTS = {"jnp", "lax", "random", "nn"}
INIT_SCOPES = {"__init__", "__post_init__", "__new__", "__init_subclass__"}

_SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass
class FunctionInfo:
    name: str
    qualname: str
    node: ast.AST                     # FunctionDef | AsyncFunctionDef
    params: list                      # ordered parameter names (incl. self)
    annotations: dict                 # param -> annotation source (or None)
    defaults: set                     # params that carry a default value
    default_nodes: dict               # param -> default value AST node
    is_method: bool                   # defined in a class body, self/cls 1st
    lexical_chain: tuple              # enclosing def names, outermost first
    in_class: Optional[str]
    jit_root: bool = False
    static_names: frozenset = frozenset()   # static_argnames of its jit
    static_nums: tuple = ()                 # static_argnums of its jit
    calls: set = dataclasses.field(default_factory=set)  # callee names

    @property
    def callable_params(self):
        """Parameters as seen from a call site (self/cls stripped)."""
        if self.is_method and self.params and self.params[0] in ("self",
                                                                "cls"):
            return self.params[1:]
        return self.params


def _ann_source(node) -> Optional[str]:
    if node is None:
        return None
    try:
        return ast.unparse(node)
    except Exception:                                  # pragma: no cover
        return None


def dotted_path(node) -> Optional[str]:
    """``state.draft_cache`` -> "state.draft_cache"; None when the chain
    is not a pure Name/Attribute spine (calls, subscripts...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jax_jit(node) -> bool:
    """True for the callable expression ``jax.jit`` or bare ``jit``."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return isinstance(node.value, ast.Name) and node.value.id == "jax"
    return isinstance(node, ast.Name) and node.id == "jit"


def jit_call(node) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` call inside ``node``, unwrapping one level of
    ``functools.partial(jax.jit, ...)`` (decorator idiom).  For partial,
    the partial call itself is returned (its keywords carry the jit
    options)."""
    if not isinstance(node, ast.Call):
        return None
    if is_jax_jit(node.func):
        return node
    # functools.partial(jax.jit, static_argnames=...) / partial(jax.jit,..)
    f = node.func
    is_partial = (isinstance(f, ast.Name) and f.id == "partial") or \
        (isinstance(f, ast.Attribute) and f.attr == "partial")
    if is_partial and node.args and is_jax_jit(node.args[0]):
        return node
    return None


def _literal_tuple(node) -> tuple:
    """Best-effort literal extraction of static_argnums/names values."""
    try:
        v = ast.literal_eval(node)
    except Exception:
        return ()
    if isinstance(v, (str, int)):
        return (v,)
    if isinstance(v, (tuple, list, set)):
        return tuple(v)
    return ()


def jit_options(call: ast.Call) -> dict:
    """donate_argnums / static_argnums / static_argnames of a jit (or
    partial-of-jit) call, as literal tuples."""
    out = {"donate_argnums": (), "static_argnums": (), "static_argnames": ()}
    for kw in call.keywords:
        if kw.arg in out:
            out[kw.arg] = _literal_tuple(kw.value)
    return out


class _FunctionCollector(ast.NodeVisitor):
    """First pass: every function with its lexical position and calls."""

    def __init__(self):
        self.functions: list[FunctionInfo] = []
        self._class_stack: list[str] = []
        self._def_stack: list[str] = []

    def _visit_def(self, node):
        args = node.args
        params = [a.arg for a in
                  args.posonlyargs + args.args + args.kwonlyargs]
        annotations = {a.arg: _ann_source(a.annotation)
                       for a in args.posonlyargs + args.args + args.kwonlyargs}
        ndef = len(args.defaults)
        pos = args.posonlyargs + args.args
        default_nodes = dict(zip([a.arg for a in pos[-ndef:]],
                                 args.defaults)) if ndef else {}
        default_nodes.update({a.arg: d for a, d in
                              zip(args.kwonlyargs, args.kw_defaults)
                              if d is not None})
        defaults = set(default_nodes)
        in_class = self._class_stack[-1] if self._class_stack and \
            not self._def_stack else None
        info = FunctionInfo(
            name=node.name,
            qualname=".".join(self._class_stack + self._def_stack
                              + [node.name]),
            node=node, params=params, annotations=annotations,
            defaults=defaults, default_nodes=default_nodes,
            is_method=in_class is not None and bool(params)
            and params[0] in ("self", "cls"),
            lexical_chain=tuple(self._def_stack),
            in_class=in_class)
        self.functions.append(info)
        self._def_stack.append(node.name)
        self.generic_visit(node)
        self._def_stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()


class ModuleModel:
    def __init__(self, source: str, path: str = "<string>"):
        self.source = source
        self.path = path
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        self.suppressions = self._collect_suppressions()

        col = _FunctionCollector()
        col.visit(self.tree)
        self.functions = col.functions
        self.by_name: dict[str, list[FunctionInfo]] = {}
        for f in self.functions:
            self.by_name.setdefault(f.name, []).append(f)
        # function body ownership: innermost enclosing FunctionInfo per node
        self._owner = {}
        for f in self.functions:
            for sub in ast.walk(f.node):
                self._owner[sub] = f        # later (inner) defs overwrite
        for f in self.functions:
            self._owner[f.node] = f

        self._mark_jit_roots()
        self._collect_calls()
        self.donators = self._collect_donators()
        self.reachable = self._reachable_set()
        self._prop: dict[int, set] = {}
        self._propagate_call_tracedness()

    # -- suppressions --------------------------------------------------
    def _collect_suppressions(self) -> dict[int, set]:
        sup: dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                sup[i] = {c.strip().upper()
                          for c in m.group(1).split(",") if c.strip()}
        return sup

    def suppressed(self, code: str, line: int) -> bool:
        for ln in (line, line - 1):
            codes = self.suppressions.get(ln)
            if codes and (code.upper() in codes or "ALL" in codes):
                return True
        return False

    # -- jit roots -----------------------------------------------------
    def _apply_jit_mark(self, fn: FunctionInfo, opts: dict):
        fn.jit_root = True
        fn.static_names = fn.static_names | frozenset(
            a for a in opts["static_argnames"] if isinstance(a, str))
        nums = tuple(a for a in opts["static_argnums"] if isinstance(a, int))
        fn.static_nums = tuple(sorted(set(fn.static_nums + nums)))
        # static_argnums index call-site positions; map them onto names
        cp = fn.callable_params
        fn.static_names = fn.static_names | frozenset(
            cp[i] for i in nums if i < len(cp))

    def _mark_jit_roots(self):
        # decorators
        for f in self.functions:
            for dec in f.node.decorator_list:
                if is_jax_jit(dec):
                    self._apply_jit_mark(f, jit_options(
                        ast.Call(func=dec, args=[], keywords=[])))
                else:
                    call = jit_call(dec)
                    if call is not None:
                        self._apply_jit_mark(f, jit_options(call))
        # jax.jit(X) call sites anywhere in the module
        for node in ast.walk(self.tree):
            call = jit_call(node)
            if call is None or call is not node:
                continue
            if not is_jax_jit(call.func):   # partial(jax.jit, ...) decorator
                continue                    # already handled above
            if not call.args:
                continue
            target = call.args[0]
            opts = jit_options(call)
            name = None
            if isinstance(target, ast.Name):
                name = target.id
            elif isinstance(target, ast.Attribute) and \
                    isinstance(target.value, ast.Name) and \
                    target.value.id in ("self", "cls"):
                name = target.attr
            if name is not None:
                for f in self.by_name.get(name, ()):
                    self._apply_jit_mark(f, opts)

    # -- call graph ----------------------------------------------------
    def _callee_name(self, call: ast.Call) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in ("self", "cls"):
            return f.attr
        return None

    def _collect_calls(self):
        for f in self.functions:
            own = set()
            for node in ast.walk(f.node):
                if self._owner.get(node) is not f:
                    continue
                if isinstance(node, ast.Call):
                    name = self._callee_name(node)
                    if name and name in self.by_name:
                        own.add(name)
                    # bare-name function arguments (closures handed to
                    # scan / tree.map / fori_loop run in-graph)
                    for a in node.args:
                        if isinstance(a, ast.Name) and a.id in self.by_name:
                            own.add(a.id)
            f.calls = own
        # nested defs are callable from their parent even if only
        # referenced via Name loads outside calls
        for f in self.functions:
            if f.lexical_chain:
                parent = f.lexical_chain[-1]
                for p in self.by_name.get(parent, ()):
                    for node in ast.walk(p.node):
                        if isinstance(node, ast.Name) and node.id == f.name \
                                and isinstance(node.ctx, ast.Load) \
                                and self._owner.get(node) is p:
                            p.calls.add(f.name)
                            break

    def _reachable_set(self) -> set:
        seen: set[int] = set()
        frontier = [f for f in self.functions if f.jit_root]
        reach = set()
        while frontier:
            f = frontier.pop()
            if id(f) in seen:
                continue
            seen.add(id(f))
            reach.add(f.qualname)
            for name in f.calls:
                frontier.extend(self.by_name.get(name, ()))
        return reach

    def is_hot(self, fn: FunctionInfo) -> bool:
        """Jit root, or reachable from one within this module."""
        return fn.jit_root or fn.qualname in self.reachable

    # -- donation registry ---------------------------------------------
    def _donating_expr(self, node, factories: dict) -> Optional[tuple]:
        """Donated call-site positions of the callable produced by
        ``node``: a ``jax.jit(target, donate_argnums=...)`` call, or a
        call to a factory whose return is one."""
        call = jit_call(node)
        if call is not None and is_jax_jit(call.func):
            donate = jit_options(call)["donate_argnums"]
            if donate:
                return tuple(int(d) for d in donate)
            return None
        if isinstance(node, ast.Call):
            name = self._callee_name(node)
            if name in factories:
                return factories[name]
        return None

    def _collect_donators(self) -> dict[str, tuple]:
        # factories: functions whose return value is a donating jit
        factories: dict[str, tuple] = {}
        for f in self.functions:
            for node in ast.walk(f.node):
                if isinstance(node, ast.Return) and node.value is not None:
                    call = jit_call(node.value)
                    if call is not None and is_jax_jit(call.func):
                        donate = jit_options(call)["donate_argnums"]
                        if donate:
                            factories[f.name] = tuple(
                                int(d) for d in donate)
        donators: dict[str, tuple] = {}
        for node in ast.walk(self.tree):
            # N = jax.jit(..., donate_argnums=...) / N = factory(...)
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                pos = self._donating_expr(node.value, factories)
                if pos:
                    tgt = dotted_path(node.targets[0])
                    if tgt:
                        donators[tgt.split(".")[-1]] = pos
            # object.__setattr__(self, "N", <donating expr>)
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "__setattr__" and len(node.args) == 3:
                pos = self._donating_expr(node.args[2], factories)
                if pos and isinstance(node.args[1], ast.Constant) and \
                        isinstance(node.args[1].value, str):
                    donators[node.args[1].value] = pos
        # decorated defs: @partial(jax.jit, donate_argnums=...)
        for f in self.functions:
            for dec in f.node.decorator_list:
                call = jit_call(dec)
                if call is not None:
                    donate = jit_options(call)["donate_argnums"]
                    if donate:
                        donators[f.name] = tuple(int(d) for d in donate)
        # transitive closure: a function forwarding its own parameter into
        # a donated position donates that parameter to its callers
        for _ in range(len(self.functions) + 1):
            grew = False
            for f in self.functions:
                mine = set(donators.get(f.name, ()))
                for node in ast.walk(f.node):
                    if not isinstance(node, ast.Call) or \
                            self._owner.get(node) is not f:
                        continue
                    key = self._donation_key(node)
                    if key is None or key not in donators:
                        continue
                    for p in donators[key]:
                        if p < len(node.args) and \
                                isinstance(node.args[p], ast.Name):
                            pname = node.args[p].id
                            cp = f.callable_params
                            if pname in cp:
                                mine.add(cp.index(pname))
                if mine and tuple(sorted(mine)) != donators.get(f.name, ()):
                    donators[f.name] = tuple(sorted(mine))
                    grew = True
            if not grew:
                break
        return donators

    def _donation_key(self, call: ast.Call) -> Optional[str]:
        """Registry key for a call expression: the bound name for
        ``f(...)``, ``self.f(...)`` and ``self._round_fn(...)`` alike."""
        f = call.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in ("self", "cls"):
            return f.attr
        return None

    # -- traced-name inference -----------------------------------------
    def traced_params(self, fn: FunctionInfo) -> set:
        traced = set(self._prop.get(id(fn), ()))
        for p in fn.params:
            if p in ("self", "cls") or p in fn.static_names:
                continue
            ann = fn.annotations.get(p)
            if ann in ARRAY_ANNOTATIONS:
                traced.add(p)
            elif fn.jit_root and ann is None and p not in fn.defaults:
                # jit ROOT: unannotated, defaultless, non-static params
                # are traced operands by construction
                traced.add(p)
        return traced - fn.static_names

    def _propagate_call_tracedness(self):
        """Call-site propagation: a HOT caller passing a traced value at
        parameter position i of a same-module callee marks that callee
        parameter traced (``helper(x)`` inside a jit root hands the
        tracer straight through).  Annotated non-Array params keep
        their annotation's word — ``deferred: bool`` style host flags
        are not promoted.  Module-wide fixpoint."""
        for _ in range(len(self.functions) + 1):
            grew = False
            for f in self.functions:
                if not self.is_hot(f):
                    continue
                traced = self.traced_names(f)
                if not traced:
                    continue
                for node in self.iter_function_nodes(f):
                    if not isinstance(node, ast.Call):
                        continue
                    name = self._callee_name(node)
                    if not name or name not in self.by_name:
                        continue
                    for callee in self.by_name[name]:
                        cp = callee.callable_params
                        slot = self._prop.setdefault(id(callee), set())
                        hits = []
                        for i, a in enumerate(node.args):
                            if i < len(cp) and \
                                    self.mentions_traced(a, traced):
                                hits.append(cp[i])
                        for kw in node.keywords:
                            if kw.arg and kw.arg in cp and \
                                    self.mentions_traced(kw.value, traced):
                                hits.append(kw.arg)
                        for p in hits:
                            ann = callee.annotations.get(p)
                            if ann is not None and \
                                    ann not in ARRAY_ANNOTATIONS:
                                continue    # annotated host param
                            if p not in slot:
                                slot.add(p)
                                grew = True
            if not grew:
                break

    def mentions_traced(self, expr, traced: set) -> Optional[str]:
        """First traced name read by ``expr`` for its VALUE — reads of
        static metadata (``x.shape``, ``len(x)``, ``x.ndim``...) do not
        count.  Returns the name, or None."""
        hit: list[str] = []

        def visit(node) -> None:
            if hit:
                return
            if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
                return                      # x.shape / x.ndim: static
            if isinstance(node, ast.Call):
                fname = node.func
                if isinstance(fname, ast.Name) and fname.id in ("len",
                                                                "isinstance",
                                                                "type"):
                    return                  # static metadata
                for child in list(node.args) + [k.value
                                                for k in node.keywords]:
                    visit(child)
                visit(node.func)
                return
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                if node.id in traced:
                    hit.append(node.id)
                return
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(expr)
        return hit[0] if hit else None

    def traced_names(self, fn: FunctionInfo) -> set:
        """Traced parameters plus names assigned from traced/jnp
        expressions, to a local fixpoint."""
        traced = self.traced_params(fn)
        assigns = []
        for node in ast.walk(fn.node):
            if self._owner.get(node) is not fn:
                continue
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                assigns.append(node)
        for _ in range(len(assigns) + 1):
            grew = False
            for node in assigns:
                value = node.value
                if value is None:
                    continue
                src = self.mentions_traced(value, traced) or \
                    self._jnp_producer(value)
                if not src:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name) and \
                                leaf.id not in traced:
                            traced.add(leaf.id)
                            grew = True
            if not grew:
                break
        return traced

    def _jnp_producer(self, expr) -> Optional[str]:
        """Does ``expr`` contain a call rooted at jnp/jax.lax/jax.random
        (producing a traced array regardless of its inputs)?"""
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                path = dotted_path(node.func)
                if path:
                    root = path.split(".")[0]
                    if root in TRACED_CALL_ROOTS or \
                            path.startswith(("jax.lax.", "jax.random.",
                                             "jax.nn.", "jnp.")):
                        return path
        return None

    # -- misc ----------------------------------------------------------
    def owner(self, node) -> Optional[FunctionInfo]:
        return self._owner.get(node)

    def iter_function_nodes(self, fn: FunctionInfo):
        """Nodes belonging to ``fn``'s own body (nested defs excluded)."""
        for node in ast.walk(fn.node):
            if self._owner.get(node) is fn:
                yield node
