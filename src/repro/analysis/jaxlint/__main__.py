"""``python -m repro.analysis.jaxlint src`` — the lint-check entry."""
import sys

from repro.analysis.jaxlint.core import main

if __name__ == "__main__":
    sys.exit(main())
