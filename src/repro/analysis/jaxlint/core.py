"""jaxlint driver: Finding type, per-file runner, CLI.

``lint_source`` builds one ``ModuleModel`` (pure ``ast`` — linted code
is never imported) and runs every registered rule over it; findings on
a line carrying (or directly under) a ``# jaxlint: disable=JLxxx``
comment are dropped.  ``lint_paths`` walks directories for ``*.py``.

CLI: ``python -m repro.analysis.jaxlint src`` — prints
``path:line:col: CODE message`` per finding, exit status 1 when any
survive (the ``make lint-check`` / CI gate contract).
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{self.message}"


# rule registry: code prefix -> check(model) -> list[Finding].  Imported
# lazily at the bottom (the rule modules import Finding from here).
RULES: dict = {}


def lint_source(source: str, path: str = "<string>",
                codes=None) -> list:
    """Lint one module's source; returns suppression-filtered findings
    sorted by position.  ``codes``: optional iterable restricting which
    rule families run (prefix match on the finding code)."""
    from repro.analysis.jaxlint.model import ModuleModel
    try:
        model = ModuleModel(source, path)
    except SyntaxError as e:
        return [Finding(code="JL000", path=path, line=e.lineno or 0,
                        col=e.offset or 0,
                        message=f"syntax error: {e.msg}")]
    findings: list = []
    for check in RULES.values():
        findings.extend(check(model))
    findings = [f for f in findings
                if not model.suppressed(f.code, f.line)]
    if codes is not None:
        findings = [f for f in findings
                    if any(f.code.startswith(c) for c in codes)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.code))


def lint_file(path, codes=None) -> list:
    p = pathlib.Path(path)
    return lint_source(p.read_text(), str(p), codes=codes)


def lint_paths(paths, codes=None) -> list:
    """Lint files and/or directories (recursed for ``*.py``)."""
    files: list[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: list = []
    for f in files:
        findings.extend(lint_file(f, codes=codes))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="repo-native static analysis for jit discipline "
                    "(see docs/STATIC_ANALYSIS.md)")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run "
                         "(e.g. JL001,JL005); default: all")
    args = ap.parse_args(argv)
    codes = [c.strip().upper() for c in args.select.split(",")] \
        if args.select else None
    findings = lint_paths(args.paths, codes=codes)
    for f in findings:
        print(f.format())
    n_files = sum(1 for raw in args.paths for _ in (
        pathlib.Path(raw).rglob("*.py")
        if pathlib.Path(raw).is_dir() else [raw]))
    if findings:
        print(f"jaxlint: {len(findings)} finding(s) in {n_files} "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"jaxlint: {n_files} file(s) clean")
    return 0


# -- rule registration (after Finding exists; rules import it from here)
from repro.analysis.jaxlint import rules_donation  # noqa: E402
from repro.analysis.jaxlint import rules_hostsync  # noqa: E402
from repro.analysis.jaxlint import rules_retrace  # noqa: E402
from repro.analysis.jaxlint import rules_sticky  # noqa: E402

RULES["JL001"] = rules_donation.check
RULES["JL002-JL004"] = rules_retrace.check
RULES["JL005"] = rules_hostsync.check
RULES["JL006"] = rules_sticky.check
