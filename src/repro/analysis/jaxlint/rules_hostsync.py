"""JL005 — host sync in the jit call tree.

Inside the round graph every value is a traced array (or, between
dispatches, an on-device buffer the host must not touch).  An operation
that needs the CONCRETE value — ``.item()``, ``int()/float()/bool()``,
``np.asarray``, interpolating an array into an f-string — either fails
under trace or, in the dispatch gap of the overlap pipeline, silently
blocks the host on the device stream, serializing the four phase
dispatches the async round exists to overlap.

Flagged inside jit roots and functions reachable from them (same-module
call-graph closure — ``ModuleModel.is_hot``):

  * ``x.item()`` / ``x.tolist()`` / ``x.block_until_ready()`` — always
    a device sync, flagged unconditionally;
  * ``np.<anything>(...)`` whose arguments read a traced value — numpy
    forces a device->host transfer of its inputs (trace-time numpy on
    static shapes/metadata is fine and not flagged);
  * ``int(x)`` / ``float(x)`` / ``bool(x)`` / ``complex(x)`` on a
    traced value — concretization;
  * f-strings interpolating a traced value — formatting concretizes.

Host-side orchestration (admission, placement views, health tracking,
stats materialization) lives OUTSIDE the jit call tree and is never
flagged; the runtime complement is the transfer-guard regression test
(tests/test_trace_guard.py) which proves the steady-state round
performs no implicit transfers at all.
"""
from __future__ import annotations

import ast

from repro.analysis.jaxlint.core import Finding
from repro.analysis.jaxlint.model import ModuleModel, dotted_path

CODE = "JL005"

SYNC_METHODS = {"item", "tolist", "block_until_ready"}
NP_ROOTS = {"np", "numpy", "onp"}
CONCRETIZERS = {"int", "float", "bool", "complex"}


def check(model: ModuleModel):
    findings = []

    def flag(node, msg):
        findings.append(Finding(code=CODE, path=model.path,
                                line=node.lineno, col=node.col_offset,
                                message=msg))

    for fn in model.functions:
        if not model.is_hot(fn):
            continue
        traced = model.traced_names(fn)
        for node in model.iter_function_nodes(fn):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and \
                        f.attr in SYNC_METHODS and not node.args:
                    flag(node, f"`.{f.attr}()` inside the jit call tree "
                               f"of `{fn.name}` — forces a device sync "
                               f"in the hot path; keep the value on "
                               f"device or move the read outside the "
                               f"round graph")
                    continue
                path = dotted_path(f)
                if path and path.split(".")[0] in NP_ROOTS and traced:
                    name = next((n for a in node.args
                                 for n in [model.mentions_traced(a, traced)]
                                 if n), None)
                    if name:
                        flag(node, f"`{path}` applied to traced value "
                                   f"`{name}` inside the jit call tree "
                                   f"of `{fn.name}` — numpy forces a "
                                   f"device->host transfer; use jnp")
                    continue
                if isinstance(f, ast.Name) and f.id in CONCRETIZERS \
                        and len(node.args) == 1 and traced:
                    name = model.mentions_traced(node.args[0], traced)
                    if name:
                        flag(node, f"`{f.id}()` concretizes traced value "
                                   f"`{name}` inside the jit call tree "
                                   f"of `{fn.name}` — a host sync (and a "
                                   f"trace error under jit)")
            elif isinstance(node, ast.JoinedStr) and traced:
                for part in node.values:
                    if isinstance(part, ast.FormattedValue):
                        name = model.mentions_traced(part.value, traced)
                        if name:
                            flag(node, f"f-string interpolates traced "
                                       f"value `{name}` inside the jit "
                                       f"call tree of `{fn.name}` — "
                                       f"formatting concretizes; use "
                                       f"jax.debug.print or move the "
                                       f"format outside the round graph")
                            break
    return findings
