"""Repo-native static analysis (`repro.analysis.jaxlint`): machine-checked
jit discipline for the serving hot path."""
