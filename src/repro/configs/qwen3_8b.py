"""qwen3-8b [dense] — qk-norm GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936  [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    mlp_act="swiglu",
    source="hf:Qwen/Qwen3-8B",
)
