"""Architecture registry: the 10 assigned archs + paper-experiment configs."""
from __future__ import annotations

from repro.configs.base import (EncoderConfig, MLAConfig, ModelConfig,
                                MoEConfig, reduced)
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.h2o_danube_3_4b import CONFIG as h2o_danube_3_4b
from repro.configs.internvl2_2b import CONFIG as internvl2_2b
from repro.configs.olmo_1b import CONFIG as olmo_1b
from repro.configs.qwen3_8b import CONFIG as qwen3_8b
from repro.configs.qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.stablelm_12b import CONFIG as stablelm_12b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.xlstm_350m import CONFIG as xlstm_350m

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c for c in (
        whisper_base, h2o_danube_3_4b, internvl2_2b, olmo_1b, xlstm_350m,
        stablelm_12b, qwen3_moe_235b_a22b, recurrentgemma_9b, qwen3_8b,
        deepseek_v2_lite_16b,
    )
}

# Input shapes assigned to this paper (name -> (seq_len, global_batch, kind))
INPUT_SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHITECTURES)}")


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


def shape_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Gate per DESIGN §4: long_500k only for sub-quadratic archs."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full attention: 500k decode cache is quadratic-cost/"
                       "linear-memory prohibitive; see DESIGN.md §4")
    return True, ""


__all__ = [
    "ARCHITECTURES", "INPUT_SHAPES", "ModelConfig", "MoEConfig", "MLAConfig",
    "EncoderConfig", "get_config", "get_reduced", "reduced",
    "shape_supported",
]
