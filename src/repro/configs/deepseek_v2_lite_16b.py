"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 2 shared + 64 routed top-6.

27L d_model=2048 16H (kv=16) d_ff=1408/expert vocab=102400  [arXiv:2405.04434]
MLA caches the 512-dim latent + 64-dim rope key instead of full K/V — the
paper's KV-cache compression.  The pool entry lists both "64e" and "160
routed"; we use the self-consistent lite dims (64 routed, top-6, 2 shared).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                    # per-expert intermediate size
    vocab_size=102400,
    block_pattern=("attn",),
    norm_type="rmsnorm",
    mlp_act="swiglu",
    mla=MLAConfig(kv_lora_rank=512, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128, q_lora_rank=0),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, d_ff_shared=1408,
                  capacity_factor=1.25),
    source="arXiv:2405.04434",
)
