"""whisper-base [audio] — encoder-decoder with stubbed conv/mel frontend.

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865  [arXiv:2212.04356]
The mel-spectrogram + conv feature extractor is a stub: ``input_specs``
supplies precomputed frame embeddings [B, 1500, 512] to the encoder.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,               # MHA
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    block_pattern=("attn",),
    pos_embedding="learned",
    norm_type="layernorm",
    mlp_act="gelu",
    encoder=EncoderConfig(num_layers=6, source_len=1500),
    frontend="audio",
    source="arXiv:2212.04356",
)
