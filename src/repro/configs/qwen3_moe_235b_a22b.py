"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, qk-norm GQA.

94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert vocab=151936
[hf:Qwen/Qwen3-30B-A3B family scaled to 235B-A22B dims]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                    # per-expert intermediate size
    vocab_size=151936,
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    mlp_act="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25),
    source="hf:Qwen/Qwen3-30B-A3B",
)
