"""internvl2-2b [vlm] — InternLM2 language backbone + stubbed InternViT.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553  [arXiv:2404.16821]
The vision encoder + MLP projector are stubbed: ``input_specs`` supplies 256
patch embeddings [B, 256, 2048] prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=("attn",),
    norm_type="rmsnorm",
    mlp_act="swiglu",
    frontend="vision",
    num_prefix_embeds=256,
    source="arXiv:2404.16821",
)
