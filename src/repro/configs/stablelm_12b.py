"""stablelm-12b [dense] — StableLM-2 family: parallel attn/MLP blocks,
partial rotary (25%), LayerNorm.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-1_6b scaled to the assigned 12B dims]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    block_pattern=("attn",),
    norm_type="layernorm",
    rope_pct=0.25,                # StableLM-2 partial rotary
    parallel_block=True,          # attn + MLP share the pre-norm input
    mlp_act="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b",
)
