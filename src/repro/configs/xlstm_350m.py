"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, attention-free.

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517]
d_ff=0: blocks carry their own up/down projections (mLSTM proj_factor=2).
Pattern follows the paper's xLSTM[7:1] ratio: 7 mLSTM then 1 sLSTM per 8.
Recurrent state is O(1) in context — runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    norm_type="rmsnorm",
    ssm_num_heads=4,
    ssm_proj_factor=2.0,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
