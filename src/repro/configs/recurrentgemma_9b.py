"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000
[arXiv:2402.19427]  38 = 12x(rglru,rglru,local_attn) + 2 remainder rglru.
Local window 2048; recurrent state O(1) — runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,               # MQA in the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    rglru_d_rnn=4096,
    conv1d_width=4,
    norm_type="rmsnorm",
    mlp_act="geglu",
    final_logit_softcap=30.0,
    source="arXiv:2402.19427",
)
