"""Unified architecture configuration.

One ``ModelConfig`` dataclass describes every assigned architecture
(dense / MoE / SSM / hybrid / VLM / audio enc-dec).  Block composition is
expressed by ``block_pattern`` — a tuple of block kinds cycled over the
layer stack — so heterogeneous stacks (RecurrentGemma's 1 local-attention :
2 RG-LRU, xLSTM's mLSTM/sLSTM mix) use the same machinery as homogeneous
transformers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0            # intermediate size of the shared path
    capacity_factor: float = 1.25   # GShard-style dense dispatch capacity
    router_aux_weight: float = 0.01 # load-balance loss weight
    # shard_map expert parallelism (§Perf it.1e): shard-local routing +
    # dispatch, explicit all-to-alls to the expert-owning model shards.
    # Off by default (pjit/GSPMD path); the dry-run/probe flips it on.
    shard_map_ep: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: int = 0            # 0 = no query compression (V2-Lite)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (Whisper)."""
    num_layers: int
    source_len: int                 # e.g. 1500 audio frames after the conv stub


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # block composition: cycled over the stack.  kinds:
    #   attn | sliding_attn | local_attn | mlstm | slstm | rglru
    block_pattern: tuple = ("attn",)
    window: int = 0                 # sliding/local attention window
    logit_softcap: float = 0.0      # attention tanh soft-capping
    final_logit_softcap: float = 0.0  # output-logit soft-capping (RecurrentGemma)

    # attention details
    qk_norm: bool = False           # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    rope_pct: float = 1.0           # partial rotary (StableLM-2: 0.25)
    pos_embedding: str = "rope"     # rope | learned | none
    mla: Optional[MLAConfig] = None

    # serving attention backend: "jnp" runs the blockwise jnp core
    # (attention.dot_attention and the paged_view gather path); "kernel"
    # dispatches prefill/decode-mode attention to the Pallas kernel
    # packages in repro.kernels (flash_prefill / flash_decode /
    # paged_flash_decode), with the jnp path as the automatic fallback
    # wherever a kernel doesn't apply (MLA, ring prefill, softcapped
    # prefill).  Train mode always uses the jnp core.
    attn_backend: str = "jnp"       # jnp | kernel

    # norms / block wiring
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm | nonparam_ln
    parallel_block: bool = False    # attn and MLP share the input (StableLM-2)
    mlp_act: str = "swiglu"         # swiglu | gelu | geglu
    tie_embeddings: bool = False

    # MoE / enc-dec / frontend
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None  # audio | vision: stubbed embedding input
    num_prefix_embeds: int = 0      # VLM: patch embeddings prepended to text

    # SSM internals
    ssm_num_heads: int = 4          # xLSTM heads
    ssm_proj_factor: float = 2.0    # mLSTM up-projection factor
    rglru_d_rnn: int = 0            # RG-LRU recurrent width (0 -> d_model)
    conv1d_width: int = 4           # temporal conv in recurrent blocks

    # numerics
    dtype: str = "float32"          # activation dtype
    param_dtype: str = "float32"

    # dry-run cost calibration: run the layer stack as an unrolled python
    # loop instead of lax.scan (XLA's cost_analysis counts a while body
    # ONCE, so scanned stacks under-report FLOPs; the dry-run compiles
    # unrolled G=1 and G=2 variants and extrapolates linearly)
    unroll_scan: bool = False

    # citation for the assigned pool entry
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (MXU lane alignment and
        16-way model-axis divisibility)."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def layer_kinds(self) -> tuple:
        """block kind of every layer (pattern cycled)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory is sub-linear in context (sliding/local
        attention, recurrent state) — gate for the long_500k shape."""
        full_attn = any(k == "attn" for k in self.layer_kinds)
        return not full_attn

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and budget derivation."""
        d, hd = self.d_model, self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n_embed = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per = {}
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora_rank or d
            attn = (d * q_in if m.q_lora_rank else 0) \
                + q_in * h * (m.qk_nope_head_dim + m.qk_rope_head_dim) \
                + d * (m.kv_lora_rank + m.qk_rope_head_dim) \
                + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim) \
                + h * m.v_head_dim * d
        else:
            attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        per["attn"] = per["sliding_attn"] = per["local_attn"] = attn
        mlp = 3 * d * self.d_ff if self.mlp_act in ("swiglu", "geglu") \
            else 2 * d * self.d_ff
        if self.moe is not None:
            mo = self.moe
            expert = 3 * d * mo.d_ff_expert
            mlp = mo.num_experts * expert + d * mo.num_experts \
                + mo.num_shared_experts * 3 * d * mo.d_ff_shared
        d_rnn = self.rglru_d_rnn or d
        per["rglru"] = 2 * d * d_rnn + d_rnn * d + d_rnn * self.conv1d_width \
            + 2 * d_rnn
        d_in = int(d * self.ssm_proj_factor)
        per["mlstm"] = 2 * d * d_in + d_in * d + 3 * d_in * d_in // self.ssm_num_heads
        per["slstm"] = 4 * d * d + 2 * d * self.d_ff if self.d_ff else 8 * d * d
        total = n_embed
        for k in self.layer_kinds:
            blk = per.get(k, attn)
            if k in ("attn", "sliding_attn", "local_attn") and self.d_ff:
                blk = blk + mlp
            total += blk
        if self.encoder is not None:
            total += self.encoder.num_layers * (attn + mlp)
        return float(total)

    def active_param_count(self) -> float:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        # MoE replaces the MLP of every attention-bearing layer
        moe_layers = sum(1 for k in self.layer_kinds
                         if k in ("attn", "sliding_attn", "local_attn"))
        unused = (mo.num_experts - mo.top_k) * 3 * self.d_model * mo.d_ff_expert
        return self.param_count() - float(unused) * moe_layers


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (<=2 layers, d<=512, <=4
    experts), per the assignment's reduced-config smoke-test rule."""
    pattern = cfg.block_pattern
    n_layers = max(2, len(pattern)) if len(pattern) > 1 else 2
    small = dict(
        num_layers=n_layers,
        d_model=min(cfg.d_model, 128),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        window=min(cfg.window, 16) if cfg.window else 0,
        rglru_d_rnn=min(cfg.rglru_d_rnn, 128) if cfg.rglru_d_rnn else 0,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 8),
    )
    if cfg.moe is not None:
        # capacity_factor = E/k makes capacity >= T: no token is ever
        # dropped, so prefill/decode exactly reproduce train logits (the
        # full configs keep the paper-realistic 1.25 dropping capacity).
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=64,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_shared=64 if cfg.moe.num_shared_experts else 0,
            capacity_factor=2.0)
    if cfg.encoder is not None:
        small["encoder"] = EncoderConfig(num_layers=2, source_len=16)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
