"""olmo-1b [dense] — non-parametric LayerNorm, MHA.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304  [arXiv:2402.00838]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    block_pattern=("attn",),
    norm_type="nonparam_ln",      # OLMo: LN without learnable affine
    mlp_act="swiglu",
    tie_embeddings=True,          # OLMo-1B ties input/output embeddings
    source="arXiv:2402.00838",
)
