"""Host-side content-addressed prefix index for paged-cache sharing.

Maps each FULL ``block_size``-token prompt-prefix block to the physical
pool block that already holds its K/V, so admission can attach a new
request's shared prompt prefix (refcount bump, zero prefill compute) and
prefill only the unique suffix.  See docs/KV_CACHE.md for the contract.

Keying: entry j of a prompt chains on the ENTIRE prefix
``tokens[: (j+1) * block_size]`` (a tuple — exact, collision-free), not
on block j's tokens alone: block j's K/V depends on every earlier token
through attention, so two prompts may share block j's physical block
only if they agree on all of its prefix.  One index per MODEL (draft and
target caches hold different K/V); the deterministic first-free
allocator gives every layer and scan group of one model the identical
block-table trajectory, so a single physical block id per (model, chain
key) covers the whole stack.

Staleness: the index only ever points at blocks whose content is the
keyed prefix.  Registered blocks are full prompt blocks behind every
write frontier — rollback never frees them (it only drops blocks past
``ceil(keep_pos / bs)`` >= the prompt's block count for live rows) and
COW never rewrites them in place — so an entry goes stale only when its
block is FREED (row release / re-admission reset).  The engine evicts at
both chokepoints: ``_release_rows`` calls ``evict_blocks`` host-side,
and admission calls ``evict_free`` + simulates its own row resets before
consulting the index.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PrefixIndex:
    """Chain-key → physical block id map for one model's paged pool."""
    by_key: dict = field(default_factory=dict)    # tuple[int,...] -> block id
    by_block: dict = field(default_factory=dict)  # block id -> chain key
    hits: int = 0
    misses: int = 0

    def match(self, tokens, block_size: int) -> list[int]:
        """Longest chain of full-block prefixes of ``tokens`` present in
        the index; returns their physical block ids in logical order.
        Stops at the first miss (block j+1 is only shareable when block
        j is)."""
        out = []
        n = len(tokens) // block_size
        toks = [int(t) for t in tokens]
        for j in range(n):
            blk = self.by_key.get(tuple(toks[: (j + 1) * block_size]))
            if blk is None:
                break
            out.append(blk)
        if out:
            self.hits += 1
        elif n:
            self.misses += 1
        return out

    def register(self, tokens, blocks, block_size: int) -> None:
        """Register every full block of ``tokens`` (physical ids
        ``blocks``, logical order).  First writer wins: an existing entry
        for a chain key is kept — its block already holds that prefix and
        may be shared by other rows."""
        n = min(len(tokens) // block_size, len(blocks))
        toks = [int(t) for t in tokens]
        for j in range(n):
            key = tuple(toks[: (j + 1) * block_size])
            blk = int(blocks[j])
            if blk < 0:
                break
            if key not in self.by_key:
                # a stale mapping for this block (freed + reallocated)
                # would have been evicted already; guard anyway
                old = self.by_block.pop(blk, None)
                if old is not None:
                    self.by_key.pop(old, None)
                self.by_key[key] = blk
                self.by_block[blk] = key

    def evict_blocks(self, blocks) -> None:
        """Drop entries for specific physical blocks (they were freed or
        are about to be reused)."""
        for blk in blocks:
            key = self.by_block.pop(int(blk), None)
            if key is not None:
                self.by_key.pop(key, None)

    def evict_free(self, refcount) -> None:
        """Drop every entry whose block's refcount is 0 — the allocator
        may hand those blocks to anyone at any time."""
        dead = [blk for blk in self.by_block if refcount[blk] == 0]
        self.evict_blocks(dead)

    def clear(self) -> None:
        self.by_key.clear()
        self.by_block.clear()
