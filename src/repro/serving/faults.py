"""Scriptable fault injection + server-health tracking for the serving
loop (churn-tolerant serving).

The paper's goodput claims are about DYNAMIC workloads, but a distributed
edge deployment is dynamic in a second way: draft servers crash, rejoin,
straggle, and sit behind degraded uplinks.  This module provides the
failure model the engine and ``LatencyModel`` consume:

* :class:`FaultEvent` / :class:`FaultPlan` — a deterministic per-round
  script of faults (the adversary), plus the mitigation knobs (verify
  ``deadline``, ``k_down`` miss threshold, suspect budget haircut, and
  whether a down server's requests ``migrate``).  ``round_faults(r)``
  compiles the plan into the dense per-round arrays the jit'd round
  consumes (:class:`RoundFaults`).
* :class:`HealthTracker` — the verify server's host-side
  healthy -> suspect -> down state machine, fed by per-round
  deadline-miss observations (engine ``RoundStats.missed``) and by
  scripted crash/rejoin events:

      healthy --miss--> suspect --(k_down consecutive misses)--> down
      suspect --on-time round--> healthy
      any     --crash event----> down
      down    --rejoin event---> healthy   (miss streak cleared)

  A DOWN server only returns via an explicit rejoin event (there is no
  probe channel in the simulation); SUSPECT servers keep drafting under
  a budget haircut so one jittery round cannot evict a healthy server.

Everything here is host-side numpy (fault scripts are I/O, like request
arrival); only :class:`RoundFaults` crosses into jit, as traced arrays so
fault values never retrace the round.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import numpy as np

# fault kinds a plan may script
FAULT_KINDS = ("crash", "rejoin", "slowdown", "uplink", "drop")

HEALTHY, SUSPECT, DOWN = "healthy", "suspect", "down"


class RoundFaults(NamedTuple):
    """Dense per-round fault arrays consumed INSIDE the jit'd round
    (``GoodSpeedEngine._reconcile_phase``).  All leaves are traced, so a
    changing fault script never retraces the round graph."""

    slow: object      # f32[N] draft-rate multiplier on arrival time (>= 1)
    uplink: object    # f32[N] uplink-transfer multiplier (>= 1 = degraded)
    dropped: object   # bool[N] payload dropped this round (forced miss)
    deadline: object  # f32[] verify deadline in seconds (inf = wait forever)

    @classmethod
    def nominal(cls, n_servers: int,
                deadline: float = math.inf) -> "RoundFaults":
        return cls(slow=np.ones((n_servers,), np.float32),
                   uplink=np.ones((n_servers,), np.float32),
                   dropped=np.zeros((n_servers,), bool),
                   deadline=np.float32(deadline))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.  ``round`` is when it takes effect; windowed
    kinds (slowdown / uplink / drop) persist for ``duration`` rounds,
    instantaneous kinds (crash / rejoin) ignore it.  ``factor`` is the
    multiplier for slowdown (draft time x factor) and uplink (transfer
    time x factor)."""

    round: int
    kind: str
    server: int
    factor: float = 1.0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")
        if self.round < 0:
            raise ValueError(f"fault round must be >= 0, got {self.round}")
        if self.server < 0:
            raise ValueError(f"fault server must be >= 0, got {self.server}")
        if self.kind in ("slowdown", "uplink") and self.factor < 1.0:
            raise ValueError(f"{self.kind} factor must be >= 1 "
                             f"(a multiplier on time), got {self.factor}")
        if self.duration < 1:
            raise ValueError(f"fault duration must be >= 1, "
                             f"got {self.duration}")

    def active_at(self, r: int) -> bool:
        return self.round <= r < self.round + self.duration


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A fault script plus the engine's mitigation configuration.

    deadline:        per-round verify deadline (seconds).  A server whose
                     simulated chunk arrival exceeds it has its round
                     dropped (zero accepted, caches rolled back) instead
                     of blocking the batch.  ``inf`` disables deadlines —
                     the no-mitigation behaviour where one straggler
                     stalls every server's round.
    k_down:          consecutive deadline misses before a server is
                     declared DOWN.
    suspect_haircut: budget multiplier (of s_max) for SUSPECT servers in
                     GOODSPEED-SCHED — a suspect keeps drafting, smaller.
    migrate:         True re-queues a down server's in-flight requests
                     (exact migration); False models the unmitigated
                     system where a crash destroys its seated requests'
                     state (they are flagged lost).
    """

    events: tuple = ()
    deadline: float = math.inf
    k_down: int = 3
    suspect_haircut: float = 0.5
    migrate: bool = True

    def __post_init__(self):
        for e in self.events:
            if not isinstance(e, FaultEvent):
                raise ValueError(f"events must be FaultEvent, got {e!r}")
        evs = tuple(sorted(self.events,
                           key=lambda e: (e.round, e.server, e.kind)))
        object.__setattr__(self, "events", evs)
        if not (self.deadline > 0.0):
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.k_down < 1:
            raise ValueError(f"k_down must be >= 1, got {self.k_down}")
        if not (0.0 < self.suspect_haircut <= 1.0):
            raise ValueError("suspect_haircut must be in (0, 1], "
                             f"got {self.suspect_haircut}")

    # -- per-round queries the serving loop makes ---------------------------
    def crashes_at(self, r: int) -> list[int]:
        return [e.server for e in self.events
                if e.kind == "crash" and e.round == r]

    def rejoins_at(self, r: int) -> list[int]:
        return [e.server for e in self.events
                if e.kind == "rejoin" and e.round == r]

    def round_faults(self, r: int, n_servers: int) -> RoundFaults:
        """Dense [N] fault arrays for round ``r`` (numpy; the engine
        converts to device arrays).  Overlapping windows of the same kind
        on one server multiply."""
        rf = RoundFaults.nominal(n_servers, self.deadline)
        for e in self.events:
            if e.server >= n_servers or not e.active_at(r):
                continue
            if e.kind == "slowdown":
                rf.slow[e.server] *= e.factor
            elif e.kind == "uplink":
                rf.uplink[e.server] *= e.factor
            elif e.kind == "drop":
                rf.dropped[e.server] = True
        return rf

    def horizon(self) -> int:
        """First round past every scripted event (0 for an empty plan)."""
        return max((e.round + e.duration for e in self.events), default=0)

    @staticmethod
    def random_plan(rng: np.random.Generator, n_servers: int, rounds: int,
                    *, deadline: float = 0.12, k_down: int = 2,
                    p_crash: float = 0.5, p_window: float = 0.7,
                    migrate: bool = True) -> "FaultPlan":
        """Random-but-recoverable plan for property tests: every crash is
        paired with a rejoin inside the horizon (so a drain can always
        complete), plus optional slowdown / uplink / drop windows."""
        events = []
        for srv in range(n_servers):
            if rng.random() < p_crash and rounds >= 4:
                c = int(rng.integers(1, max(2, rounds // 2)))
                j = int(rng.integers(c + 1, max(c + 2, 3 * rounds // 4)))
                events.append(FaultEvent(round=c, kind="crash", server=srv))
                events.append(FaultEvent(round=j, kind="rejoin", server=srv))
            if rng.random() < p_window and rounds >= 4:
                kind = rng.choice(("slowdown", "uplink", "drop"))
                start = int(rng.integers(0, max(1, rounds // 2)))
                dur = int(rng.integers(1, 4))
                events.append(FaultEvent(
                    round=start, kind=str(kind), server=srv,
                    factor=float(rng.uniform(2.0, 30.0)), duration=dur))
        return FaultPlan(events=tuple(events), deadline=deadline,
                         k_down=k_down, migrate=migrate)


class HealthTracker:
    """Host-side healthy/suspect/down state machine over the N draft
    servers, driven by the engine's per-round deadline-miss observations
    and the plan's crash/rejoin events (module docstring has the
    transition diagram)."""

    def __init__(self, n_servers: int, k_down: int = 3,
                 suspect_haircut: float = 0.5):
        self.n = n_servers
        self.k_down = k_down
        self.suspect_haircut = suspect_haircut
        self.status = [HEALTHY] * n_servers
        self.miss_streak = np.zeros((n_servers,), np.int64)
        self._newly_down: list[int] = []
        self.counts = {"misses": 0, "down_events": 0, "rejoin_events": 0}

    # -- scripted events ----------------------------------------------------
    def crash(self, server: int) -> None:
        """A crash is immediately DOWN — no suspect grace."""
        if self.status[server] != DOWN:
            self.status[server] = DOWN
            self._newly_down.append(server)
            self.counts["down_events"] += 1
        self.miss_streak[server] = 0

    def rejoin(self, server: int) -> bool:
        """Returns True when the server was actually down (the caller
        re-warms its quarantined estimator state on a real rejoin)."""
        self.miss_streak[server] = 0
        if self.status[server] == DOWN:
            self.status[server] = HEALTHY
            self.counts["rejoin_events"] += 1
            return True
        self.status[server] = HEALTHY
        return False

    # -- per-round observation ----------------------------------------------
    def observe_round(self, drafted: np.ndarray, missed: np.ndarray) -> None:
        """Fold one round of engine observations: ``drafted`` (bool[N],
        server had S > 0) and ``missed`` (bool[N], its chunk blew the
        deadline / was dropped).  Servers that did not draft hold their
        state, mirroring the estimator's hold-on-unobserved contract."""
        for i in range(self.n):
            if self.status[i] == DOWN or not bool(drafted[i]):
                continue
            if bool(missed[i]):
                self.counts["misses"] += 1
                self.miss_streak[i] += 1
                if self.miss_streak[i] >= self.k_down:
                    self.status[i] = DOWN
                    self._newly_down.append(i)
                    self.counts["down_events"] += 1
                else:
                    self.status[i] = SUSPECT
            else:
                self.miss_streak[i] = 0
                self.status[i] = HEALTHY

    def take_newly_down(self) -> list[int]:
        """Servers that transitioned to DOWN since the last call (the
        engine migrates their requests exactly once)."""
        out, self._newly_down = self._newly_down, []
        return out

    # -- views the serving loop consumes ------------------------------------
    def available(self) -> np.ndarray:
        """bool[N]: not DOWN (placement views exclude unavailable
        servers; seating onto one is gated in the request manager)."""
        return np.asarray([s != DOWN for s in self.status], bool)

    def apply_caps(self, caps: np.ndarray, lanes: int,
                   s_max: int) -> np.ndarray:
        """GOODSPEED-SCHED masking: DOWN servers' lane caps -> 0 (their
        verify budget flows to live servers inside the solver), SUSPECT
        servers' caps are haircut to ``ceil(s_max * suspect_haircut)``
        per lane so a slow server costs the batch less while it proves
        itself."""
        caps = np.asarray(caps, np.int32).copy()
        haircut = max(1, int(math.ceil(s_max * self.suspect_haircut)))
        for i, st in enumerate(self.status):
            rows = slice(i * lanes, (i + 1) * lanes)
            if st == DOWN:
                caps[rows] = 0
            elif st == SUSPECT:
                caps[rows] = np.minimum(caps[rows], haircut)
        return caps

    def summary(self) -> dict:
        return {"status": list(self.status),
                "miss_streak": self.miss_streak.tolist(),
                **self.counts}
