"""Request lifecycle management for the verification server (paper §III-A).

The verification server "manages a FIFO queue to process requests in the
order of arrival".  Each draft server carries one ACTIVE request at a time
(its end-user session); when a request completes (max_new_tokens reached or
EOS), the next queued request for that server is admitted immediately —
continuous batching at the server granularity.  The engine reads
``remaining`` caps from here and feeds them to GOODSPEED-SCHED as s_max
(completion-aware allocation, EXPERIMENTS §Repro).

Host-side bookkeeping by design (request arrival is I/O, not jit-able);
everything the jit'd round loop needs is exported as dense arrays.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # i32[prompt_len]
    max_new_tokens: int
    eos_token: int = -1             # -1 = no EOS check
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # lifecycle
    generated: list = dataclasses.field(default_factory=list)
    arrival_round: int = 0
    admit_round: Optional[int] = None
    finish_round: Optional[int] = None
    # paged-KV accounting: blocks the admission prefill allocated for this
    # request (0 under static caches); set by the engine at admission
    kv_blocks: int = 0

    @property
    def remaining(self) -> int:
        return max(0, self.max_new_tokens - len(self.generated))

    @property
    def done(self) -> bool:
        if self.remaining == 0:
            return True
        return self.eos_token >= 0 and self.eos_token in self.generated


class RequestManager:
    """Per-draft-server FIFO queues + active-request slots."""

    def __init__(self, n_servers: int):
        self.n = n_servers
        self.queues: list[deque] = [deque() for _ in range(n_servers)]
        self.active: list[Optional[Request]] = [None] * n_servers
        self.completed: list[Request] = []
        self.round = 0

    # -- admission ----------------------------------------------------------
    def submit(self, server: int, request: Request) -> None:
        request.arrival_round = self.round
        self.queues[server].append(request)

    def retire_done(self) -> list[int]:
        """Move done active requests to ``completed``; returns their
        servers.  A done request retires even when its queue is empty —
        the slot goes idle (``remaining_caps`` reports 0) rather than
        holding a finished request forever."""
        retired = []
        for i in range(self.n):
            if self.active[i] is not None and self.active[i].done:
                self.active[i].finish_round = self.round
                self.completed.append(self.active[i])
                self.active[i] = None
                retired.append(i)
        return retired

    def admit(self) -> list[int]:
        """Retire done active requests, then fill empty slots from the FIFO
        queues; returns servers that got a NEW request this call (their
        caches need re-prefilling)."""
        self.retire_done()
        fresh = []
        for i in range(self.n):
            if self.active[i] is None and self.queues[i]:
                self.active[i] = self.queues[i].popleft()
                self.active[i].admit_round = self.round
                fresh.append(i)
        return fresh

    # -- round bookkeeping ---------------------------------------------------
    def record_emitted(self, emitted: np.ndarray) -> None:
        """emitted: i32[N, S+1], -1 padded (engine RoundStats.emitted).

        Tokens are truncated at the request's cap AND at the first EOS
        token (the EOS itself is kept so ``done`` observes it); anything
        past EOS never enters ``generated``, keeping ``remaining``, goodput
        accounting, and returned text consistent with completion."""
        for i in range(self.n):
            req = self.active[i]
            if req is None:
                continue
            toks = [int(t) for t in emitted[i] if t >= 0]
            if req.eos_token >= 0 and req.eos_token in toks:
                toks = toks[: toks.index(req.eos_token) + 1]
            room = req.remaining
            req.generated.extend(toks[:room])
        self.round += 1

    def tick(self) -> None:
        """Advance the round clock without emissions — an all-idle round
        spent waiting for future arrivals."""
        self.round += 1

    # -- dense views for the jit'd loop --------------------------------------
    def remaining_caps(self) -> np.ndarray:
        """i32[N] remaining tokens per server (0 where idle or done — an
        EOS-finished request may have cap budget left but must not be
        scheduled) — feeds GOODSPEED-SCHED's s_max."""
        return np.asarray(
            [r.remaining if r is not None and not r.done else 0
             for r in self.active], np.int32)

    def idle(self) -> bool:
        """True when nothing is in flight anywhere (drain detection)."""
        return all(r is None or r.done for r in self.active) \
            and not any(self.queues)

    def stats(self) -> dict:
        lat = [r.finish_round - r.arrival_round for r in self.completed]
        qd = [r.admit_round - r.arrival_round for r in self.completed
              if r.admit_round is not None]
        return {
            "completed": len(self.completed),
            "queued": sum(len(q) for q in self.queues),
            "active": sum(r is not None and not r.done for r in self.active),
            "mean_latency_rounds": float(np.mean(lat)) if lat else 0.0,
            "mean_queue_delay_rounds": float(np.mean(qd)) if qd else 0.0,
            "tokens_generated": sum(len(r.generated) for r in self.completed),
            # paged-KV view: blocks held by in-flight requests (prompt
            # allocation; decode growth allocates beyond this) — 0 under
            # static caches
            "kv_blocks_active": sum(r.kv_blocks for r in self.active
                                    if r is not None and not r.done),
        }
