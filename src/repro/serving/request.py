"""Request lifecycle management for the verification server (paper §III-A).

The verification server "manages a FIFO queue to process requests in the
order of arrival".  Arrivals land in ONE global cross-server queue; a
pluggable :class:`repro.serving.placement.PlacementPolicy` routes each
request to a draft server — ``static`` binds on arrival and reproduces
the original per-server FIFO affinity exactly, while ``jsq``/``goodput``
hold requests in the global queue and decide the server at SEAT time
against the live view, so a request is never stuck behind a binding that
turned out to be the hot server (see ``placement.py``).  Each draft server
carries up to ``lanes`` ACTIVE requests at a time (its end-user sessions,
batched through the engine's draft lanes); when a request completes
(max_new_tokens reached or EOS), the next queued request is seated into
the freed lane immediately — continuous batching at lane granularity.
The engine reads per-lane ``remaining`` caps from here; GOODSPEED-SCHED
aggregates them per server (the paper's fairness unit) and a water-filling
splitter divides each server's allocation across its live lanes
(completion-aware allocation, EXPERIMENTS §Repro).

Host-side bookkeeping by design (request arrival is I/O, not jit-able);
everything the jit'd round loop needs is exported as dense arrays.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import numpy as np

from repro.serving.placement import (PlacementView, fits_pool,
                                     make_placement)

_ids = itertools.count()


@dataclasses.dataclass(eq=False)    # identity equality: requests are
class Request:                      # queue entries, and the generated
    # field-wise __eq__ would compare numpy prompts (ambiguous truth)
    prompt: np.ndarray              # i32[prompt_len]
    max_new_tokens: int
    eos_token: int = -1             # -1 = no EOS check
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # lifecycle
    generated: list = dataclasses.field(default_factory=list)
    arrival_round: int = 0
    admit_round: Optional[int] = None
    finish_round: Optional[int] = None
    # placement: the server the submitter asked for (static affinity), the
    # server the policy actually chose, and the lane (request slot on that
    # server) the manager seated it in — placement decides the SERVER only;
    # the lane is the lowest free slot (deterministic)
    server_hint: Optional[int] = None
    placed_server: Optional[int] = None
    placed_lane: Optional[int] = None
    # rounds spent waiting (arrival -> admission); aged by the manager
    # every round-clock advance while the request is still queued, so wait
    # metrics are honest for requests that have not been admitted yet
    queue_wait: int = 0
    # paged-KV accounting: blocks this request's row currently holds,
    # recomputed from the live block table every round by the engine
    # (``_refresh_kv_blocks``); 0 under static caches.  Under prefix
    # sharing a block referenced r times counts 1/r per holder (a float),
    # so kv_blocks summed over seated requests equals allocated blocks.
    kv_blocks: float = 0
    # churn bookkeeping: times this request was migrated off a DOWN server
    # (returned to the global queue with its committed tokens preserved),
    # and the unmitigated-crash fate — a ``lost`` request's server died
    # with its state and no migration ran, so it can never finish (its
    # lane reports zero cap forever; FaultPlan(migrate=False) baseline)
    migrations: int = 0
    lost: bool = False

    @property
    def remaining(self) -> int:
        return max(0, self.max_new_tokens - len(self.generated))

    @property
    def done(self) -> bool:
        if self.remaining == 0:
            return True
        return self.eos_token >= 0 and self.eos_token in self.generated


class RequestManager:
    """Global arrival queue + placement + active-request slots.

    ``placement`` is a policy name (``static`` | ``jsq`` | ``goodput``) or
    a ``PlacementPolicy`` instance.  Arrivals wait in ``self.arrivals``;
    ``admit`` seats them against a live :class:`PlacementView` (estimator
    state, queue loads, free KV blocks) supplied by the engine — or a
    self-derived view when driven directly.  Binding-on-arrival policies
    park arrivals on per-server FIFO queues first; lazy policies seat
    straight from the global queue.

    ``lanes``: concurrent request slots PER SERVER (the engine's draft
    lanes).  ``self.active`` is row-indexed, server-major — row
    ``srv * lanes + lane`` — matching the engine's [N*R] batch layout;
    queues, hints and placement decisions stay at SERVER granularity, and
    a seated request takes the lowest free lane of its chosen server.
    ``lanes=1`` is exactly the one-request-per-server manager.
    """

    def __init__(self, n_servers: int, placement="static", lanes: int = 1):
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        if n_servers < 1:
            raise ValueError(f"n_servers must be >= 1, got {n_servers}")
        self.n = n_servers
        self.lanes = lanes
        self.rows = n_servers * lanes
        self.placement = make_placement(placement)
        self.arrivals: deque = deque()             # global cross-server
        self.queues: list[deque] = [deque() for _ in range(n_servers)]
        self.active: list[Optional[Request]] = [None] * self.rows
        self.completed: list[Request] = []
        self.round = 0
        # server availability (health tracker view): DOWN servers take no
        # bindings and seat no requests until they rejoin
        self.available = np.ones((n_servers,), bool)

    # -- (server, lane) <-> row ----------------------------------------------
    def server_of(self, row: int) -> int:
        return row // self.lanes

    def _free_row(self, server: int) -> Optional[int]:
        """Lowest free row (lane) of ``server``; None when all lanes busy."""
        for row in range(server * self.lanes, (server + 1) * self.lanes):
            if self.active[row] is None:
                return row
        return None

    # -- admission ----------------------------------------------------------
    def submit(self, server: Optional[int], request: Request) -> None:
        """Enqueue an arrival.  ``server`` is the submitter's affinity hint
        (binding under static placement, advisory otherwise; None is only
        valid for non-static policies).  Misuse — a hint outside
        [0, n_servers), a non-positive token cap, a missing static hint —
        is rejected HERE at the submission site with a clear ValueError,
        not rounds later as a shape error deep inside the jit'd round."""
        if server is None and self.placement.name == "static":
            raise ValueError("static placement needs a server hint: "
                             "submit(server, request)")
        if server is not None and not 0 <= int(server) < self.n:
            raise ValueError(
                f"server hint {server} out of range for {self.n} draft "
                f"servers (valid: 0..{self.n - 1}, or None under a "
                f"non-static placement)")
        if request.max_new_tokens <= 0:
            raise ValueError(
                f"request {request.request_id} has non-positive "
                f"max_new_tokens={request.max_new_tokens}; the scheduler "
                f"would never allocate it a draft budget")
        request.arrival_round = self.round
        request.server_hint = None if server is None else int(server)
        self.arrivals.append(request)

    def queue_load(self) -> np.ndarray:
        """i64[N] queued token demand (sum of remaining budgets) per
        server.  Only binding-on-arrival policies (static) park requests
        on per-server queues; under lazy policies this is all zeros and
        the balance signal is ``active_remaining``."""
        return np.asarray([sum(r.remaining for r in q) for q in self.queues],
                          np.int64)

    def _default_view(self) -> PlacementView:
        """Self-derived view for direct-driven managers (no engine): queue
        state only, cold estimates, no pool gate."""
        return PlacementView(queue_load=self.queue_load(),
                             active_remaining=self.server_remaining())

    def _bind_arrivals(self, view: PlacementView) -> None:
        """Binding-on-arrival policies only (static affinity): drain the
        global arrival queue onto the per-server FIFO queues, in arrival
        order.  Lazy policies (jsq/goodput) skip this — their requests
        stay in the global queue until a slot can seat them, so every
        decision runs against live state instead of a stale binding.
        A request bound to an UNAVAILABLE (down) server stays in the
        global queue until that server rejoins — static affinity means
        its binding cannot be rerouted."""
        held = deque()
        while self.arrivals:
            req = self.arrivals.popleft()
            srv = self.placement.place(req, view) % self.n
            if not self.available[srv]:
                held.append(req)
                continue
            self.queues[srv].append(req)
            view.note_placed(req, srv)
        self.arrivals = held

    def _oldest_candidate(self, skip: set):
        """(server_or_None, request): the longest-waiting request that
        could be seated — the head of a per-server queue whose slot is
        free (and whose server is available), or the oldest global
        arrival not in ``skip`` (server decided by the policy at seat
        time).  None when nothing is seatable."""
        best = None
        for i in range(self.n):
            if self.available[i] and self._free_row(i) is not None \
                    and self.queues[i]:
                r = self.queues[i][0]
                key = (r.arrival_round, r.request_id)
                if best is None or key < best[0]:
                    best = (key, i, r)
        for r in self.arrivals:
            if r.request_id not in skip:
                key = (r.arrival_round, r.request_id)
                if best is None or key < best[0]:
                    best = (key, None, r)
                break                      # arrivals deque is FIFO
        return None if best is None else (best[1], best[2])

    def retire_done(self) -> list[int]:
        """Move done active requests to ``completed``; returns their rows
        (server-major ``srv * lanes + lane``).  A done request retires even
        when its queue is empty — the slot goes idle (``remaining_caps``
        reports 0) rather than holding a finished request forever."""
        retired = []
        for i in range(self.rows):
            if self.active[i] is not None and self.active[i].done:
                self.active[i].finish_round = self.round
                self.completed.append(self.active[i])
                self.active[i] = None
                retired.append(i)
        return retired

    def admit(self, view: Optional[PlacementView] = None) -> list[int]:
        """Retire done active requests, then seat waiting requests —
        oldest first — until nothing more fits; returns the ROWS
        (server-major ``srv * lanes + lane``) that got a NEW request this
        call (their cache rows need re-prefilling).  The policy picks the
        server; the manager seats into its lowest free lane.

        Binding-on-arrival policies (static) first drain arrivals onto
        their per-server queues; lazy policies (jsq/goodput) seat
        straight from the global queue, the policy choosing the server at
        SEAT time against the live view — a request whose chosen server
        is still busy simply keeps waiting (re-decided next round, never
        bound to a stale choice).

        Under paged KV (``view.free_blocks`` set) a request whose first
        round cannot fit the free block list is DEFERRED — it stays
        queued and keeps aging — instead of letting the admission prefill
        raise ``PoolExhaustedError``.  Seating stops at the first request
        that cannot proceed, so freed blocks flow to the longest-waiting
        request instead of being snatched by later small arrivals (no
        unbounded starvation under pool pressure)."""
        if view is None:
            view = self._default_view()
        if self.placement.binds_on_arrival:
            self._bind_arrivals(view)
        self.retire_done()
        fresh: list = []
        waiting: set = set()
        while True:
            cand = self._oldest_candidate(waiting)
            if cand is None:
                break
            srv, req = cand
            if srv is None:                 # global head: decide NOW
                srv = self.placement.place(req, view) % self.n
                if not self.available[srv] or self._free_row(srv) is None:
                    # the policy prefers waiting for this busy (or still
                    # down) server — the request keeps waiting, but
                    # younger candidates may still seat on OTHER free
                    # slots: they cannot take the slot this request is
                    # holding out for
                    waiting.add(req.request_id)
                    continue
            if not fits_pool(req, view):
                break                       # pool pressure: elder first
            if self.queues[srv] and self.queues[srv][0] is req:
                self.queues[srv].popleft()
            else:
                self.arrivals.remove(req)
            row = self._free_row(srv)
            req.admit_round = self.round
            req.placed_server = srv
            req.placed_lane = row % self.lanes
            self.active[row] = req
            view.note_admitted(req, srv)
            fresh.append(row)
        return sorted(fresh)

    # -- server churn (faults/health integration) ----------------------------
    def set_available(self, available: np.ndarray) -> None:
        """Server availability mask (``HealthTracker.available()``): DOWN
        servers take no new bindings and seat no requests until rejoin."""
        available = np.asarray(available, bool)
        if available.shape != (self.n,):
            raise ValueError(f"availability mask must be bool[{self.n}], "
                             f"got shape {available.shape}")
        self.available = available

    def evict_server(self, server: int) -> list[int]:
        """EXACT request migration off a DOWN server: every in-flight
        request (all lanes) returns to the GLOBAL arrival queue with its
        committed tokens preserved (``generated`` is append-only, so
        re-admission re-prefills from prompt + generated and the emitted
        sequence continues exactly where it stopped); requests the server
        had bound-but-unseated (static affinity queue) return too.
        Returns the freed rows (the engine releases their paged KV
        blocks).  A request that was already done is completed, not
        re-queued.  The global queue is re-sorted by (arrival_round,
        request_id) afterwards — ``_oldest_candidate`` peeks only the
        deque head and relies on that age order."""
        if not 0 <= server < self.n:
            raise ValueError(f"server {server} out of range "
                             f"(0..{self.n - 1})")
        freed, moved = [], []
        for row in range(server * self.lanes, (server + 1) * self.lanes):
            req = self.active[row]
            if req is None:
                continue
            self.active[row] = None
            freed.append(row)
            if req.done:
                req.finish_round = self.round
                self.completed.append(req)
                continue
            req.placed_server = None
            req.placed_lane = None
            req.migrations += 1
            moved.append(req)
        while self.queues[server]:
            moved.append(self.queues[server].popleft())
        if moved:
            self.arrivals.extend(moved)
            self.arrivals = deque(sorted(
                self.arrivals, key=lambda r: (r.arrival_round,
                                              r.request_id)))
        return freed

    def mark_lost(self, server: int) -> list[int]:
        """No-mitigation crash model (``FaultPlan(migrate=False)``): the
        server's seated requests lose their state with it and are flagged
        ``lost`` — they stay seated (blocking their lanes, as an
        unoperated deployment would) but report zero cap forever and can
        never complete.  Bound-but-unseated requests keep waiting: they
        had no server state to lose and seat again if the server rejoins.
        Returns the lost rows."""
        if not 0 <= server < self.n:
            raise ValueError(f"server {server} out of range "
                             f"(0..{self.n - 1})")
        rows = []
        for row in range(server * self.lanes, (server + 1) * self.lanes):
            req = self.active[row]
            if req is not None and not req.done and not req.lost:
                req.lost = True
                rows.append(row)
        return rows

    # -- round bookkeeping ---------------------------------------------------
    def _age_queued(self) -> None:
        """One round passed with these requests still waiting."""
        for req in self.arrivals:
            req.queue_wait += 1
        for q in self.queues:
            for req in q:
                req.queue_wait += 1

    def record_emitted(self, emitted: np.ndarray) -> None:
        """emitted: i32[N*R, S+1], -1 padded, server-major rows (engine
        RoundStats.emitted).

        Tokens are truncated at the request's cap AND at the first EOS
        token (the EOS itself is kept so ``done`` observes it); anything
        past EOS never enters ``generated``, keeping ``remaining``, goodput
        accounting, and returned text consistent with completion."""
        for i in range(self.rows):
            req = self.active[i]
            if req is None:
                continue
            toks = [int(t) for t in emitted[i] if t >= 0]
            if req.eos_token >= 0 and req.eos_token in toks:
                toks = toks[: toks.index(req.eos_token) + 1]
            room = req.remaining
            req.generated.extend(toks[:room])
        self._age_queued()
        self.round += 1

    def tick(self) -> None:
        """Advance the round clock without emissions — an all-idle round
        spent waiting for future arrivals.  Queued-but-unplaced requests
        age here too, so their wait metrics stay honest."""
        self._age_queued()
        self.round += 1

    # -- dense views for the jit'd loop --------------------------------------
    def remaining_caps(self) -> np.ndarray:
        """i32[N*R] remaining tokens per ROW, server-major (0 where idle or
        done — an EOS-finished request may have cap budget left but must
        not be scheduled) — feeds the engine's per-lane caps, which the
        scheduler aggregates per server and the lane splitter divides."""
        return np.asarray(
            [r.remaining if r is not None and not r.done and not r.lost
             else 0 for r in self.active], np.int32)

    def server_remaining(self) -> np.ndarray:
        """i32[N] remaining tokens per SERVER (lane caps summed) — the
        placement view's ``active_remaining`` signal."""
        return self.remaining_caps().reshape(
            self.n, self.lanes).sum(axis=1).astype(np.int32)

    def idle(self) -> bool:
        """True when nothing is in flight anywhere (drain detection)."""
        return all(r is None or r.done for r in self.active) \
            and not any(self.queues) and not self.arrivals

    def stats(self) -> dict:
        lat = [r.finish_round - r.arrival_round for r in self.completed]
        qd = [r.admit_round - r.arrival_round for r in self.completed
              if r.admit_round is not None]
        queued = list(self.arrivals) + [r for q in self.queues for r in q]
        live = [r for r in self.active if r is not None]
        admitted = live + self.completed
        per_server = np.zeros((self.n,), np.int64)
        for r in admitted:
            if r.placed_server is not None:
                per_server[r.placed_server] += 1
            elif r.server_hint is not None:    # legacy direct submission
                per_server[r.server_hint] += 1
        return {
            "completed": len(self.completed),
            "queued": len(queued),
            "active": sum(not r.done for r in live),
            # churn accounting: total migrations across requests, and
            # requests whose server crashed unmitigated (lost state,
            # can never complete — always 0 when migration is on)
            "migrations": sum(r.migrations for r in admitted + queued),
            "requests_lost": sum(1 for r in live if r.lost),
            "mean_latency_rounds": float(np.mean(lat)) if lat else 0.0,
            "mean_queue_delay_rounds": float(np.mean(qd)) if qd else 0.0,
            "tokens_generated": sum(len(r.generated) for r in self.completed),
            # per-request queue-wait ticks (arrival -> admission), INCLUDING
            # still-queued requests at their current age — the benchmark's
            # p50/p95 wait comes from here
            "queue_wait_ticks": {r.request_id: r.queue_wait
                                 for r in admitted + queued},
            # requests each server has admitted (starvation diagnostics)
            "per_server_admitted": per_server.tolist(),
            # paged-KV view: blocks held by in-flight requests (prompt
            # allocation; decode growth allocates beyond this) — 0 under
            # static caches
            "kv_blocks_active": sum(r.kv_blocks for r in live if not r.done),
        }
