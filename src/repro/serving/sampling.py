"""Sampling transforms for draft generation and correction sampling.

The GoodSpeed engine samples draft tokens from q and corrections from the
residual distribution; this module provides the standard serving transforms
(temperature / top-k / top-p / min-p) as *logit warpers* so they compose and
stay jit-friendly.  IMPORTANT for speculative decoding: whatever warping the
draft server applies defines q — the verifier must see the warped logits or
rejection sampling loses its losslessness guarantee (see
tests/test_sampling.py::test_warped_q_losslessness).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0          # 0 = disabled
    top_p: float = 1.0      # 1.0 = disabled
    min_p: float = 0.0      # 0.0 = disabled


def warp_logits(logits: Array, params: SamplingParams) -> Array:
    """Apply temperature -> top-k -> top-p -> min-p.  logits: [..., V]."""
    if params.temperature != 1.0:
        logits = logits / max(params.temperature, 1e-6)
    if params.top_k > 0 and params.top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -params.top_k][..., None]
        logits = jnp.where(logits < kth, NEG, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, NEG, logits)
    if params.min_p > 0.0:
        probs = jax.nn.softmax(logits, axis=-1)
        top = jnp.max(probs, axis=-1, keepdims=True)
        logits = jnp.where(probs < params.min_p * top, NEG, logits)
    return logits


def sample(key: Array, logits: Array, params: SamplingParams | None = None
           ) -> Array:
    """Categorical sample after warping; returns i32[...]."""
    if params is not None:
        logits = warp_logits(logits, params)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
