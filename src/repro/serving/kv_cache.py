"""KV caches: full (static-length), sliding-window (ring buffer), MLA
latent, and paged (block-structured pool) variants.

Static layout is [B, L, KV, hd] with the cache-length axis L second so it
can be sharded over the ``model`` mesh axis for decode (sequence-sharded
flash-decode).  Every cache carries an explicit per-slot absolute-position
array (``pos_arr``, -1 = empty) so attention masks are layout-independent —
the same masking code covers left-aligned full caches, wrapped ring
buffers, and block-table views.  See docs/KV_CACHE.md for the layout and
masking contract.

Static chunk writes use masked broadcast selects rather than scatters:
elementwise on the sharded L axis, so GSPMD never needs to reshuffle the
cache to write one token.

Paged caches (``PagedAttnCache`` / ``PagedMLACache``) replace the per-row
[L, ...] storage with a shared block pool ``[P, block_size, ...]`` plus a
per-row block table ``i32[B, M]`` and a per-block reference count
``i32[P]`` (free ⟺ refcount 0): retiring a request decrements its blocks'
refcounts; admitting a new one allocates only the blocks its prompt
needs, so admission cost is independent of the batch size.  Writes
allocate blocks from the free list in-graph (deterministic first-free
order) and scatter into the pool; attention gathers a logical
[B, M*block_size, ...] view through the table.

Refcounts > 1 are how PREFIX SHARING works: several rows' tables point at
one physical block holding their common prompt prefix
(``paged_write_prefill``'s ``shared_blocks`` argument attaches existing
blocks instead of allocating), and a write landing in a shared block
copy-on-writes it first (``paged_write_chunk``) so no row can clobber
another row's K/V.  The refcount/COW invariants are documented (and
property-tested) in docs/KV_CACHE.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AttnCache(NamedTuple):
    k: Array         # [B, L, KV, hd]
    v: Array         # [B, L, KV, hd]
    pos_arr: Array   # i32[B, L] absolute position stored in each slot, -1 empty
    next_pos: Array  # i32[B] next absolute position to write
    overflowed: Array  # bool[B] sticky: a write past slot L was dropped


class MLACache(NamedTuple):
    ckv: Array       # [B, L, r]     latent
    kpe: Array       # [B, L, rope]  decoupled rope key
    pos_arr: Array
    next_pos: Array
    overflowed: Array


class PagedAttnCache(NamedTuple):
    """Block-structured GQA cache: shared pool + per-row block table.

    Logical slot l of row b lives at physical pool slot
    ``table[b, l // bs] * bs + l % bs`` (bs = block_size = kpool.shape[1]).
    ``table`` entries are -1 until a block is allocated; ``refcount[p]``
    counts the table cells referencing pool block p (0 = free; > 1 = the
    block is SHARED between rows via prefix caching and is copy-on-write).
    ``alloc_failed`` is a sticky scalar set when a write needed a block
    and the pool was exhausted (the write is dropped); ``overflowed`` is
    the per-row analogue for writes past the row's logical capacity.
    Hosts check both after admission/prefill and every serving round.
    """
    kpool: Array         # [P, bs, KV, hd]
    vpool: Array         # [P, bs, KV, hd]
    table: Array         # i32[B, M]  physical block per logical block, -1
    refcount: Array      # i32[P]     table cells referencing each block
    pos_arr: Array       # i32[B, M*bs] absolute position per slot, -1 empty
    next_pos: Array      # i32[B]
    alloc_failed: Array  # bool[]     sticky pool-exhaustion flag
    overflowed: Array    # bool[B]    sticky row-capacity-overflow flag

    @property
    def free(self) -> Array:
        """bool[P] free mask (refcount 0) — the allocator's search order
        and every host-side free count read this view."""
        return self.refcount == 0


class PagedMLACache(NamedTuple):
    """Block-structured MLA latent cache (same table contract as
    ``PagedAttnCache``; the pool holds latents + decoupled rope keys)."""
    ckv_pool: Array      # [P, bs, r]
    kpe_pool: Array      # [P, bs, rope]
    table: Array
    refcount: Array
    pos_arr: Array
    next_pos: Array
    alloc_failed: Array
    overflowed: Array

    @property
    def free(self) -> Array:
        return self.refcount == 0


PAGED_TYPES = (PagedAttnCache, PagedMLACache)


class PoolExhaustedError(RuntimeError):
    """Raised by admission when the block pool cannot hold a new request's
    prompt — a clean host-level error instead of silent dropped writes."""


class CacheOverflowError(RuntimeError):
    """Raised by the serving loop when a row's sticky ``overflowed`` flag
    is set: a chunk write ran past the row's logical capacity and was
    dropped — the row's generation is missing K/V and cannot continue."""


def init_attn_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                    dtype) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        overflowed=jnp.zeros((batch,), bool),
    )


def init_mla_cache(batch: int, length: int, rank: int, rope_dim: int,
                   dtype) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, length, rank), dtype),
        kpe=jnp.zeros((batch, length, rope_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        overflowed=jnp.zeros((batch,), bool),
    )


def init_paged_attn_cache(batch: int, length: int, kv_heads: int,
                          head_dim: int, dtype, block_size: int = 16,
                          num_blocks: int = 0) -> PagedAttnCache:
    """Paged GQA cache with logical per-row capacity >= ``length``.

    num_blocks = 0 sizes the pool so every row can reach full logical
    capacity (batch * ceil(length / block_size)) — the "never worse than
    static" default; pass a smaller pool to actually oversubscribe."""
    m = -(-length // block_size)
    p = num_blocks or batch * m
    return PagedAttnCache(
        kpool=jnp.zeros((p, block_size, kv_heads, head_dim), dtype),
        vpool=jnp.zeros((p, block_size, kv_heads, head_dim), dtype),
        table=jnp.full((batch, m), -1, jnp.int32),
        refcount=jnp.zeros((p,), jnp.int32),
        pos_arr=jnp.full((batch, m * block_size), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        alloc_failed=jnp.zeros((), bool),
        overflowed=jnp.zeros((batch,), bool),
    )


def init_paged_mla_cache(batch: int, length: int, rank: int, rope_dim: int,
                         dtype, block_size: int = 16,
                         num_blocks: int = 0) -> PagedMLACache:
    m = -(-length // block_size)
    p = num_blocks or batch * m
    return PagedMLACache(
        ckv_pool=jnp.zeros((p, block_size, rank), dtype),
        kpe_pool=jnp.zeros((p, block_size, rope_dim), dtype),
        table=jnp.full((batch, m), -1, jnp.int32),
        refcount=jnp.zeros((p,), jnp.int32),
        pos_arr=jnp.full((batch, m * block_size), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        alloc_failed=jnp.zeros((), bool),
        overflowed=jnp.zeros((batch,), bool),
    )


# ---------------------------------------------------------------------------
# Paged primitives
# ---------------------------------------------------------------------------

def _paged_pools(cache):
    if isinstance(cache, PagedMLACache):
        return [cache.ckv_pool, cache.kpe_pool]
    return [cache.kpool, cache.vpool]


def _paged_replace(cache, pools, **kw):
    if isinstance(cache, PagedMLACache):
        return cache._replace(ckv_pool=pools[0], kpe_pool=pools[1], **kw)
    return cache._replace(kpool=pools[0], vpool=pools[1], **kw)


def paged_block_size(cache) -> int:
    return _paged_pools(cache)[0].shape[1]


def paged_over_groups(fn, *caches):
    """Apply a per-layer paged op to cache leaves that may carry a leading
    scan-group axis (init_stack_cache stacks identical layers [G, ...]),
    vmapping over the group axis when present.  Per-call operands that
    are batch-indexed (keep_pos, row masks, row indices) must be closed
    over in ``fn`` — they are shared across groups, not mapped."""
    if caches[0].next_pos.ndim == 2:
        return jax.vmap(fn)(*caches)
    return fn(*caches)


def _nth_free(free: Array, rank: Array) -> Array:
    """Physical id of the rank-th (0-based) free block; P if exhausted.
    Deterministic first-free order keeps every layer's table identical."""
    cs = jnp.cumsum(free.astype(jnp.int32))
    return jnp.searchsorted(cs, rank + 1).astype(jnp.int32)


def _scatter_tokens(pools, new_values, flat_idx):
    """Scatter per-token slices into flattened pools.  flat_idx: i32[B, S]
    physical flat slot per token (out-of-range drops the write)."""
    out = []
    for pool, new in zip(pools, new_values):
        p, bs = pool.shape[:2]
        flat = pool.reshape((p * bs,) + pool.shape[2:])
        flat = flat.at[flat_idx].set(new.astype(pool.dtype), mode="drop")
        out.append(flat.reshape(pool.shape))
    return out


def paged_write_chunk(cache, new_values: tuple, chunk_valid: Array | None):
    """Append an S-token chunk, allocating pool blocks as rows cross block
    boundaries.  Same semantics as the static ``write_chunk`` (invalid
    steps don't advance); a row that needs a block when the pool is empty
    drops the write and sets ``alloc_failed``; a row whose counter reached
    the logical capacity drops the write and sets its sticky
    ``overflowed`` flag (the write is NEVER clamped onto the last slot —
    that silently destroyed the previous token's K/V).

    Copy-on-write: a write landing in a block with refcount > 1 (prefix
    sharing) first copies that block to a fresh one, repoints this row's
    table at the copy and decrements the shared block — the other rows'
    K/V is immutable.  The engine's full-block-only sharing means COW
    never fires in normal serving (shared prompt blocks are complete and
    behind every write frontier); it is the safety net that makes the
    primitive correct for ANY caller."""
    pools = _paged_pools(cache)
    bs = pools[0].shape[1]
    p = pools[0].shape[0]
    b, m = cache.table.shape
    l = cache.pos_arr.shape[1]
    s = new_values[0].shape[1]

    def body(t, carry):
        pools, table, refcount, pos_arr, next_pos, failed, over = carry
        ok = chunk_valid[:, t] if chunk_valid is not None \
            else jnp.ones((b,), bool)
        over = over | (ok & (next_pos >= l))
        ok = ok & (next_pos < l)
        slot = jnp.minimum(next_pos, l - 1)
        blk, off = slot // bs, slot % bs
        cur = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]
        shared = ok & (cur >= 0) & (refcount[jnp.maximum(cur, 0)] > 1)
        needs = ok & ((cur < 0) | shared)
        rank = jnp.cumsum(needs.astype(jnp.int32)) - 1
        cand = _nth_free(refcount == 0, rank)
        got = needs & (cand < p)
        failed = failed | jnp.any(needs & (cand >= p))
        refcount = refcount.at[jnp.where(got, cand, p)].add(1, mode="drop")
        # COW: copy the shared block's contents into the fresh block and
        # drop this row's reference to the original
        cow = shared & got
        refcount = refcount.at[jnp.where(cow, cur, p)].add(-1, mode="drop")
        src = jnp.maximum(cur, 0)
        dst = jnp.where(cow, cand, p)
        pools = [pool.at[dst].set(pool[src], mode="drop") for pool in pools]
        table = table.at[jnp.arange(b), blk].set(
            jnp.where(got, cand, cur))
        phys_blk = jnp.where(got, cand, cur)
        # a shared block whose COW allocation failed must NOT be written:
        # the dropped write may not corrupt the other rows' K/V
        can = ok & (phys_blk >= 0) & ~(shared & ~got)
        flat = jnp.where(can, phys_blk * bs + off, p * bs)
        pools = _scatter_tokens(pools, [nv[:, t][:, None] for nv in
                                        new_values], flat[:, None])
        pos_arr = pos_arr.at[jnp.arange(b),
                             jnp.where(can, slot, l)].set(
            next_pos, mode="drop")
        next_pos = jnp.where(can, next_pos + 1, next_pos)
        return pools, table, refcount, pos_arr, next_pos, failed, over

    (pools, table, refcount, pos_arr, next_pos, failed,
     over) = jax.lax.fori_loop(
        0, s, body, (pools, cache.table, cache.refcount, cache.pos_arr,
                     cache.next_pos, cache.alloc_failed, cache.overflowed))
    return _paged_replace(cache, pools, table=table, refcount=refcount,
                          pos_arr=pos_arr, next_pos=next_pos,
                          alloc_failed=failed, overflowed=over)


def paged_write_prefill(cache, new_values: tuple, lengths: Array,
                        shared_blocks: Array | None = None,
                        shared_lens: Array | None = None):
    """Bulk-fill the rows of this cache view from a left-aligned prefill
    chunk.  Any blocks the rows previously held are released first
    (re-prefilling a live row cannot leak).

    Without sharing: allocates exactly ceil(lengths / block_size) blocks
    per row and scatters token j to logical slot j.

    With prefix sharing (``shared_blocks``: i32[B, Ms] existing physical
    block ids, -1 padded; ``shared_lens``: i32[B] tokens those blocks
    already hold — a whole-block multiple): row b's table slots
    0..count_b-1 ATTACH to the given blocks (refcount bump, no compute,
    no writes — the blocks' K/V is immutable while shared) and the chunk
    holds only the UNIQUE SUFFIX: token j scatters to logical slot
    shared_lens[b] + j, allocating only the suffix's blocks.  Attachment
    happens BEFORE suffix allocation, so a shared block just released by
    this call's own row reset (its content still intact) is re-pinned
    rather than reallocated."""
    cache = paged_reset_rows(cache, jnp.ones(cache.table.shape[:1], bool))
    pools = _paged_pools(cache)
    bs = pools[0].shape[1]
    p = pools[0].shape[0]
    b, m = cache.table.shape
    l = cache.pos_arr.shape[1]
    s = new_values[0].shape[1]
    refcount, table = cache.refcount, cache.table    # rows all reset (-1)
    if shared_blocks is None:
        start = jnp.zeros((b,), jnp.int32)
    else:
        start = shared_lens.astype(jnp.int32)
        ms = shared_blocks.shape[1]
        attach = shared_blocks >= 0                               # [B, Ms]
        refcount = refcount.at[
            jnp.where(attach, shared_blocks, p).reshape(-1)].add(
            1, mode="drop")
        head = jnp.where(attach, shared_blocks, -1)
        table = jnp.concatenate(
            [head, jnp.full((b, m - ms), -1, jnp.int32)], axis=1) \
            if m > ms else head
    total = start + lengths.astype(jnp.int32)
    # block j of row b is needed iff it holds any position < total[b]
    # and is not already attached
    needs = ((jnp.arange(m)[None, :] * bs) < total[:, None]) \
        & (table < 0)                                             # [B, M]
    rank = (jnp.cumsum(needs.reshape(-1).astype(jnp.int32)) - 1).reshape(b, m)
    cand = _nth_free(refcount == 0, rank)
    got = needs & (cand < p)
    failed = cache.alloc_failed | jnp.any(needs & (cand >= p))
    refcount = refcount.at[jnp.where(got, cand, p).reshape(-1)].add(
        1, mode="drop")
    table = jnp.where(got, cand, table)
    # scatter the S suffix tokens (logical slot == absolute position)
    tok_slot = start[:, None] + jnp.arange(s)[None, :]
    phys_blk = jnp.take_along_axis(table,
                                   jnp.minimum(tok_slot // bs, m - 1), axis=1)
    can = (jnp.arange(s)[None, :] < lengths[:, None]) & (phys_blk >= 0) \
        & (tok_slot < l)
    flat = jnp.where(can, phys_blk * bs + tok_slot % bs, p * bs)
    pools = _scatter_tokens(pools, list(new_values), flat)
    idx = jnp.arange(l)[None, :]
    # a slot is valid only when its block allocation succeeded: an
    # unbacked-but-valid slot would gather block 0 (another request's
    # K/V) through paged_view's safe indexing
    backed = jnp.take_along_axis(table, idx // bs, axis=1) >= 0
    pos_arr = jnp.where((idx < total[:, None]) & backed, idx, -1)
    return _paged_replace(cache, pools, table=table, refcount=refcount,
                          pos_arr=pos_arr, next_pos=total,
                          alloc_failed=failed,
                          overflowed=cache.overflowed | (total > l))


def paged_rollback(cache, keep_pos: Array):
    """Invalidate slots holding positions >= keep_pos AND release the
    speculative-tail blocks (logical blocks past ceil(keep_pos / bs)):
    each dropped table entry decrements its block's refcount, and the
    block returns to the pool only when the count reaches 0 (another row
    may still share it)."""
    bs = paged_block_size(cache)
    m = cache.table.shape[1]
    keep_blocks = -(-keep_pos // bs)                              # ceil
    drop = (jnp.arange(m)[None, :] >= keep_blocks[:, None]) \
        & (cache.table >= 0)
    p = cache.refcount.shape[0]
    refcount = cache.refcount.at[
        jnp.where(drop, cache.table, p).reshape(-1)].add(-1, mode="drop")
    return cache._replace(
        table=jnp.where(drop, -1, cache.table), refcount=refcount,
        pos_arr=jnp.where(cache.pos_arr >= keep_pos[:, None], -1,
                          cache.pos_arr),
        next_pos=jnp.minimum(cache.next_pos, keep_pos))


def paged_reset_rows(cache, rows: Array):
    """Release ALL blocks of the selected rows (bool[B]) — request
    retirement.  Each table entry decrements its block's refcount (free
    at 0; shared blocks survive until their last reference drops), and
    the rows' sticky ``overflowed`` flags clear with the rows."""
    p = cache.refcount.shape[0]
    sel = rows[:, None] & (cache.table >= 0)
    refcount = cache.refcount.at[
        jnp.where(sel, cache.table, p).reshape(-1)].add(-1, mode="drop")
    return cache._replace(
        table=jnp.where(rows[:, None], -1, cache.table), refcount=refcount,
        pos_arr=jnp.where(rows[:, None], -1, cache.pos_arr),
        next_pos=jnp.where(rows, 0, cache.next_pos),
        overflowed=jnp.where(rows, False, cache.overflowed))


def paged_view(cache):
    """Gather the logical [B, L, ...] view of each pool through the block
    table (L = M * block_size).  Unallocated blocks read block 0; their
    slots are masked by ``pos_arr == -1`` so attention never sees them."""
    bs = paged_block_size(cache)
    b, m = cache.table.shape
    safe = jnp.maximum(cache.table, 0)
    out = []
    for pool in _paged_pools(cache):
        v = pool[safe]                                  # [B, M, bs, ...]
        out.append(v.reshape((b, m * bs) + pool.shape[2:]))
    return out


def paged_select_rows(cache, idx: Array):
    """Row-slice of the per-row state (table/pos_arr/next_pos); the pool
    and free list stay shared, so writes through the slice land in the
    same physical memory.  Inverse: ``paged_merge_rows``."""
    return cache._replace(table=cache.table[idx],
                          pos_arr=cache.pos_arr[idx],
                          next_pos=cache.next_pos[idx],
                          overflowed=cache.overflowed[idx])


def paged_merge_rows(full, sub, idx: Array):
    """Merge a row-slice back: per-row state scatters into ``idx``; pool,
    refcounts and alloc flag come from the slice (they are the shared,
    already-updated allocator state)."""
    pools = _paged_pools(sub)
    return _paged_replace(
        full, pools,
        table=full.table.at[idx].set(sub.table),
        refcount=sub.refcount,
        pos_arr=full.pos_arr.at[idx].set(sub.pos_arr),
        next_pos=full.next_pos.at[idx].set(sub.next_pos),
        alloc_failed=sub.alloc_failed,
        overflowed=full.overflowed.at[idx].set(sub.overflowed))


def paged_free_count(cache) -> Array:
    """Number of unallocated pool blocks (device scalar)."""
    return jnp.sum(cache.free.astype(jnp.int32))


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Host helper: blocks needed to hold n_tokens cache slots."""
    return -(-max(0, int(n_tokens)) // block_size)


def _write_one(values, pos_arr, next_pos, new_slices, ring):
    """Write one token (time index t of the chunk) into each value array.

    values: list of [B, L, ...]; new_slices: list of [B, ...] (no L axis).
    Non-ring rows at capacity (next_pos >= L) DROP the write and freeze
    their counter (never clamp onto slot L-1 — that destroyed the last
    token's K/V); the returned bool[B] flags those rows.  Ring caches
    wrap by design and never overflow.
    """
    l = pos_arr.shape[1]
    if ring:
        over = jnp.zeros(next_pos.shape, bool)
        slot = next_pos % l
    else:
        over = next_pos >= l
        slot = jnp.minimum(next_pos, l - 1)
    hit = (jnp.arange(l)[None, :] == slot[:, None]) & ~over[:, None]
    out = []
    for val, new in zip(values, new_slices):
        mask = hit.reshape(hit.shape + (1,) * (val.ndim - 2))
        out.append(jnp.where(mask, new[:, None].astype(val.dtype), val))
    pos_arr = jnp.where(hit, next_pos[:, None], pos_arr)
    return out, pos_arr, jnp.where(over, next_pos, next_pos + 1), over


def write_chunk(cache, new_values: tuple, chunk_valid: Array | None = None,
                ring: bool = False):
    """Append an S-token chunk.  new_values: tuple of [B, S, ...] arrays
    matching the cache's value fields.  chunk_valid: bool[B, S] marks real
    tokens (ragged verify batches); invalid steps don't advance the cache.

    Implemented as a fori over S masked writes — S is small on the
    decode/verify path (1..C tokens).  Prefill uses ``write_prefill``.
    """
    if isinstance(cache, PAGED_TYPES):
        return paged_write_chunk(cache, new_values, chunk_valid)
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    s = new_values[0].shape[1]

    def body(t, carry):
        vals, pos_arr, next_pos, over = carry
        slices = [nv[:, t] for nv in new_values]
        new_vals, new_pos_arr, new_next, over_t = _write_one(
            vals, pos_arr, next_pos, slices, ring)
        if chunk_valid is not None:
            ok = chunk_valid[:, t]
            new_vals = [jnp.where(ok.reshape((-1,) + (1,) * (v.ndim - 1)), nv, v)
                        for nv, v in zip(new_vals, vals)]
            new_pos_arr = jnp.where(ok[:, None], new_pos_arr, pos_arr)
            new_next = jnp.where(ok, new_next, next_pos)
            over_t = over_t & ok
        return new_vals, new_pos_arr, new_next, over | over_t

    vals, pos_arr, next_pos, over = jax.lax.fori_loop(
        0, s, body, (vals, cache.pos_arr, cache.next_pos, cache.overflowed))
    if is_mla:
        return cache._replace(ckv=vals[0], kpe=vals[1], pos_arr=pos_arr,
                              next_pos=next_pos, overflowed=over)
    return cache._replace(k=vals[0], v=vals[1], pos_arr=pos_arr,
                          next_pos=next_pos, overflowed=over)


def write_prefill(cache, new_values: tuple, lengths: Array,
                  ring: bool = False,
                  shared_blocks: Array | None = None,
                  shared_lens: Array | None = None):
    """Bulk-fill an empty cache from a left-aligned prefill chunk.

    new_values: tuple of [B, S, ...] with S <= L; lengths: i32[B] valid
    prefix length per row.  For ring caches S may exceed the window — only
    the last ``window`` positions land (computed with a shifted write).
    ``shared_blocks``/``shared_lens`` (paged only) attach an existing
    shared prompt prefix per row — see ``paged_write_prefill``.
    """
    if isinstance(cache, PAGED_TYPES):
        return paged_write_prefill(cache, new_values, lengths,
                                   shared_blocks=shared_blocks,
                                   shared_lens=shared_lens)
    assert shared_blocks is None, "prefix sharing requires a paged cache"
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    b, l = cache.pos_arr.shape
    s = new_values[0].shape[1]
    idx = jnp.arange(l)[None, :]                              # [1, L]
    if not ring:
        assert s <= l, f"prefill chunk {s} exceeds cache {l}"
        out_vals = []
        for val, new in zip(vals, new_values):
            pad = jnp.zeros(val.shape[:1] + (l - s,) + val.shape[2:], val.dtype)
            full = jnp.concatenate([new.astype(val.dtype), pad], axis=1)
            out_vals.append(full)
        pos_arr = jnp.where(idx < lengths[:, None], idx, -1)
    else:
        # slot of absolute position p is p % L; gather source index per slot
        start = jnp.maximum(lengths - l, 0)                   # first kept pos
        # slot j holds absolute position p with p ≡ j (mod L), start<=p<len
        candidate = start[:, None] + (idx - start[:, None]) % l
        valid = candidate < lengths[:, None]
        src = jnp.clip(candidate, 0, s - 1)
        out_vals = []
        for val, new in zip(vals, new_values):
            sidx = src.reshape(b, l, *(1,) * (val.ndim - 2)).astype(jnp.int32)
            gathered = jnp.take_along_axis(new.astype(val.dtype), sidx, axis=1)
            out_vals.append(jnp.where(
                valid.reshape(b, l, *(1,) * (val.ndim - 2)), gathered, val))
        pos_arr = jnp.where(valid, candidate, -1)
    next_pos = lengths.astype(jnp.int32)
    over = jnp.zeros(cache.overflowed.shape, bool)   # rows fully replaced
    if is_mla:
        return cache._replace(ckv=out_vals[0], kpe=out_vals[1],
                              pos_arr=pos_arr, next_pos=next_pos,
                              overflowed=over)
    return cache._replace(k=out_vals[0], v=out_vals[1], pos_arr=pos_arr,
                          next_pos=next_pos, overflowed=over)


def rollback(cache, keep_pos: Array):
    """Speculative-decoding rollback: invalidate every slot holding an
    absolute position >= keep_pos[b] (rejected draft tokens).  Paged
    caches additionally return the freed tail blocks to the pool."""
    if isinstance(cache, PAGED_TYPES):
        return paged_over_groups(lambda c: paged_rollback(c, keep_pos),
                                 cache)
    drop = cache.pos_arr >= keep_pos[:, None]
    return cache._replace(pos_arr=jnp.where(drop, -1, cache.pos_arr),
                          next_pos=jnp.minimum(cache.next_pos, keep_pos))


def snapshot_alloc_flag(cache) -> Array | None:
    """Draft-tail snapshot for one-round-late rollback: the sticky
    ``alloc_failed`` flag BEFORE a speculative draft-ahead writes its
    tail.  Everything else the ahead-chunk touches is restored exactly by
    ``discard_tail`` (slot invalidation + tail-block free), but a pool
    allocation that failed only because of discarded ahead-writes must
    not poison the sticky flag — so the engine snapshots it at dispatch
    and ``discard_tail`` writes it back.  Returns a traced bool scalar
    (group 0 of stacked leaves; all groups share one allocator
    trajectory), or None for non-paged caches (nothing sticky to
    restore)."""
    if isinstance(cache, PAGED_TYPES):
        return cache.alloc_failed[0] if cache.next_pos.ndim == 2 \
            else cache.alloc_failed
    return None


class StickyFlags(NamedTuple):
    """Pre-ahead snapshot of every sticky error flag a speculative
    draft-ahead can transiently set (see ``snapshot_sticky_flags``)."""
    alloc_failed: Array | None   # bool[]  (None for static caches)
    overflowed: Array            # bool[B]


def snapshot_sticky_flags(cache) -> StickyFlags:
    """Snapshot BOTH sticky flags before a speculative draft-ahead:
    ``alloc_failed`` (paged; see ``snapshot_alloc_flag``) and the per-row
    ``overflowed`` flag (all cache kinds) — an ahead-write that ran past
    capacity but is then discarded must not poison either.  Refcounts
    need no snapshot: ``discard_tail``'s decrements mirror the ahead
    writes' increments exactly (full-block-only sharing means COW never
    fires on the fresh tail blocks an ahead-chunk allocates)."""
    over = cache.overflowed
    if over.ndim == 2:                      # stacked scan-group leaves:
        over = over[0]                      # one shared write trajectory
    return StickyFlags(alloc_failed=snapshot_alloc_flag(cache),
                       overflowed=over)


def discard_tail(cache, keep_pos: Array, alloc_failed: Array | None = None,
                 overflowed: Array | None = None):
    """One-round-late rollback of a speculative draft-ahead (overlap
    mode): identical to ``rollback`` — the ahead-tail's slots invalidate
    and its paged blocks return to the pool — except the sticky
    ``alloc_failed`` / ``overflowed`` flags are restored to their
    pre-ahead snapshots (``snapshot_sticky_flags``).  With ``keep_pos =
    length + min(accepted+1, S)`` this lands the cache bit-exactly on the
    state a synchronous round would have produced: the deferred discard
    differs from the sync rollback only when the whole chunk was
    accepted, where it additionally drops the ahead-root's write at
    position length+S — a slot the synchronous round never wrote."""
    if isinstance(cache, PAGED_TYPES):
        def f(c):
            r = paged_rollback(c, keep_pos)
            if alloc_failed is not None:
                r = r._replace(alloc_failed=alloc_failed)
            if overflowed is not None:
                r = r._replace(overflowed=overflowed)
            return r
        return paged_over_groups(f, cache)
    out = rollback(cache, keep_pos)
    if overflowed is not None:
        over = overflowed
        if out.overflowed.ndim == 2:        # stacked scan-group leaves
            over = jnp.broadcast_to(over[None], out.overflowed.shape)
        out = out._replace(overflowed=over)
    return out


def reset_rows(cache, rows: Array):
    """Invalidate ALL slots of the selected rows (bool[B]) — used when a
    fresh request is admitted into a draft-server slot.  Stale K/V values
    stay in memory but are unreachable (pos_arr == -1 masks them); paged
    caches instead free the rows' blocks for immediate reuse."""
    if isinstance(cache, PAGED_TYPES):
        return paged_over_groups(lambda c: paged_reset_rows(c, rows),
                                 cache)
    return cache._replace(
        pos_arr=jnp.where(rows[:, None], -1, cache.pos_arr),
        next_pos=jnp.where(rows, 0, cache.next_pos),
        overflowed=jnp.where(rows, False, cache.overflowed))


def prefill_rows(cache, new_values: tuple, lengths: Array, rows: Array,
                 ring: bool = False):
    """Per-row re-prefill: rows where ``rows[b]`` is True are replaced by a
    fresh prefill of ``new_values``/``lengths`` (see ``write_prefill``);
    all other rows keep their existing contents untouched.  Single-cache
    primitive of the continuous-batching admission row-turnover; the
    serving engine applies the same row-select at the stack-cache level
    (``engine._merge_cache_rows``) since per-layer K/V is produced inside
    ``model.forward``."""
    fresh = write_prefill(reset_rows(cache, rows), new_values, lengths,
                          ring=ring)

    def sel(new, old):
        mask = rows.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(mask, new, old)

    return jax.tree.map(sel, fresh, cache)
