"""KV caches: full (static-length), sliding-window (ring buffer), MLA
latent, and paged (block-structured pool) variants.

Static layout is [B, L, KV, hd] with the cache-length axis L second so it
can be sharded over the ``model`` mesh axis for decode (sequence-sharded
flash-decode).  Every cache carries an explicit per-slot absolute-position
array (``pos_arr``, -1 = empty) so attention masks are layout-independent —
the same masking code covers left-aligned full caches, wrapped ring
buffers, and block-table views.  See docs/KV_CACHE.md for the layout and
masking contract.

Static chunk writes use masked broadcast selects rather than scatters:
elementwise on the sharded L axis, so GSPMD never needs to reshuffle the
cache to write one token.

Paged caches (``PagedAttnCache`` / ``PagedMLACache``) replace the per-row
[L, ...] storage with a shared block pool ``[P, block_size, ...]`` plus a
per-row block table ``i32[B, M]`` and a free mask ``bool[P]``: retiring a
request frees its blocks; admitting a new one allocates only the blocks
its prompt needs, so admission cost is independent of the batch size.
Writes allocate blocks from the free list in-graph (deterministic
first-free order) and scatter into the pool; attention gathers a logical
[B, M*block_size, ...] view through the table.  The free-list invariants
are documented (and property-tested) in docs/KV_CACHE.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AttnCache(NamedTuple):
    k: Array         # [B, L, KV, hd]
    v: Array         # [B, L, KV, hd]
    pos_arr: Array   # i32[B, L] absolute position stored in each slot, -1 empty
    next_pos: Array  # i32[B] next absolute position to write


class MLACache(NamedTuple):
    ckv: Array       # [B, L, r]     latent
    kpe: Array       # [B, L, rope]  decoupled rope key
    pos_arr: Array
    next_pos: Array


class PagedAttnCache(NamedTuple):
    """Block-structured GQA cache: shared pool + per-row block table.

    Logical slot l of row b lives at physical pool slot
    ``table[b, l // bs] * bs + l % bs`` (bs = block_size = kpool.shape[1]).
    ``table`` entries are -1 until a block is allocated; ``free[p]`` marks
    pool block p as unallocated.  ``alloc_failed`` is a sticky scalar set
    when a write needed a block and the pool was exhausted (the write is
    dropped); hosts check it after admission/prefill.
    """
    kpool: Array         # [P, bs, KV, hd]
    vpool: Array         # [P, bs, KV, hd]
    table: Array         # i32[B, M]  physical block per logical block, -1
    free: Array          # bool[P]    block unallocated
    pos_arr: Array       # i32[B, M*bs] absolute position per slot, -1 empty
    next_pos: Array      # i32[B]
    alloc_failed: Array  # bool[]     sticky pool-exhaustion flag


class PagedMLACache(NamedTuple):
    """Block-structured MLA latent cache (same table contract as
    ``PagedAttnCache``; the pool holds latents + decoupled rope keys)."""
    ckv_pool: Array      # [P, bs, r]
    kpe_pool: Array      # [P, bs, rope]
    table: Array
    free: Array
    pos_arr: Array
    next_pos: Array
    alloc_failed: Array


PAGED_TYPES = (PagedAttnCache, PagedMLACache)


class PoolExhaustedError(RuntimeError):
    """Raised by admission when the block pool cannot hold a new request's
    prompt — a clean host-level error instead of silent dropped writes."""


def init_attn_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                    dtype) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
    )


def init_mla_cache(batch: int, length: int, rank: int, rope_dim: int,
                   dtype) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, length, rank), dtype),
        kpe=jnp.zeros((batch, length, rope_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
    )


def init_paged_attn_cache(batch: int, length: int, kv_heads: int,
                          head_dim: int, dtype, block_size: int = 16,
                          num_blocks: int = 0) -> PagedAttnCache:
    """Paged GQA cache with logical per-row capacity >= ``length``.

    num_blocks = 0 sizes the pool so every row can reach full logical
    capacity (batch * ceil(length / block_size)) — the "never worse than
    static" default; pass a smaller pool to actually oversubscribe."""
    m = -(-length // block_size)
    p = num_blocks or batch * m
    return PagedAttnCache(
        kpool=jnp.zeros((p, block_size, kv_heads, head_dim), dtype),
        vpool=jnp.zeros((p, block_size, kv_heads, head_dim), dtype),
        table=jnp.full((batch, m), -1, jnp.int32),
        free=jnp.ones((p,), bool),
        pos_arr=jnp.full((batch, m * block_size), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        alloc_failed=jnp.zeros((), bool),
    )


def init_paged_mla_cache(batch: int, length: int, rank: int, rope_dim: int,
                         dtype, block_size: int = 16,
                         num_blocks: int = 0) -> PagedMLACache:
    m = -(-length // block_size)
    p = num_blocks or batch * m
    return PagedMLACache(
        ckv_pool=jnp.zeros((p, block_size, rank), dtype),
        kpe_pool=jnp.zeros((p, block_size, rope_dim), dtype),
        table=jnp.full((batch, m), -1, jnp.int32),
        free=jnp.ones((p,), bool),
        pos_arr=jnp.full((batch, m * block_size), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
        alloc_failed=jnp.zeros((), bool),
    )


# ---------------------------------------------------------------------------
# Paged primitives
# ---------------------------------------------------------------------------

def _paged_pools(cache):
    if isinstance(cache, PagedMLACache):
        return [cache.ckv_pool, cache.kpe_pool]
    return [cache.kpool, cache.vpool]


def _paged_replace(cache, pools, **kw):
    if isinstance(cache, PagedMLACache):
        return cache._replace(ckv_pool=pools[0], kpe_pool=pools[1], **kw)
    return cache._replace(kpool=pools[0], vpool=pools[1], **kw)


def paged_block_size(cache) -> int:
    return _paged_pools(cache)[0].shape[1]


def paged_over_groups(fn, *caches):
    """Apply a per-layer paged op to cache leaves that may carry a leading
    scan-group axis (init_stack_cache stacks identical layers [G, ...]),
    vmapping over the group axis when present.  Per-call operands that
    are batch-indexed (keep_pos, row masks, row indices) must be closed
    over in ``fn`` — they are shared across groups, not mapped."""
    if caches[0].next_pos.ndim == 2:
        return jax.vmap(fn)(*caches)
    return fn(*caches)


def _nth_free(free: Array, rank: Array) -> Array:
    """Physical id of the rank-th (0-based) free block; P if exhausted.
    Deterministic first-free order keeps every layer's table identical."""
    cs = jnp.cumsum(free.astype(jnp.int32))
    return jnp.searchsorted(cs, rank + 1).astype(jnp.int32)


def _scatter_tokens(pools, new_values, flat_idx):
    """Scatter per-token slices into flattened pools.  flat_idx: i32[B, S]
    physical flat slot per token (out-of-range drops the write)."""
    out = []
    for pool, new in zip(pools, new_values):
        p, bs = pool.shape[:2]
        flat = pool.reshape((p * bs,) + pool.shape[2:])
        flat = flat.at[flat_idx].set(new.astype(pool.dtype), mode="drop")
        out.append(flat.reshape(pool.shape))
    return out


def paged_write_chunk(cache, new_values: tuple, chunk_valid: Array | None):
    """Append an S-token chunk, allocating pool blocks as rows cross block
    boundaries.  Same semantics as the static ``write_chunk`` (invalid
    steps don't advance); a row that needs a block when the pool is empty
    drops the write and sets ``alloc_failed``."""
    pools = _paged_pools(cache)
    bs = pools[0].shape[1]
    p = pools[0].shape[0]
    b, m = cache.table.shape
    l = cache.pos_arr.shape[1]
    s = new_values[0].shape[1]

    def body(t, carry):
        pools, table, free, pos_arr, next_pos, failed = carry
        ok = chunk_valid[:, t] if chunk_valid is not None \
            else jnp.ones((b,), bool)
        slot = jnp.minimum(next_pos, l - 1)
        blk, off = slot // bs, slot % bs
        cur = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]
        needs = ok & (cur < 0)
        rank = jnp.cumsum(needs.astype(jnp.int32)) - 1
        cand = _nth_free(free, rank)
        got = needs & (cand < p)
        failed = failed | jnp.any(needs & (cand >= p))
        free = free.at[jnp.where(got, cand, p)].set(False, mode="drop")
        table = table.at[jnp.arange(b), blk].set(
            jnp.where(got, cand, cur))
        phys_blk = jnp.where(got, cand, cur)
        can = ok & (phys_blk >= 0)
        flat = jnp.where(can, phys_blk * bs + off, p * bs)
        pools = _scatter_tokens(pools, [nv[:, t][:, None] for nv in
                                        new_values], flat[:, None])
        pos_arr = pos_arr.at[jnp.arange(b),
                             jnp.where(can, slot, l)].set(
            next_pos, mode="drop")
        next_pos = jnp.where(can, next_pos + 1, next_pos)
        return pools, table, free, pos_arr, next_pos, failed

    pools, table, free, pos_arr, next_pos, failed = jax.lax.fori_loop(
        0, s, body, (pools, cache.table, cache.free, cache.pos_arr,
                     cache.next_pos, cache.alloc_failed))
    return _paged_replace(cache, pools, table=table, free=free,
                          pos_arr=pos_arr, next_pos=next_pos,
                          alloc_failed=failed)


def paged_write_prefill(cache, new_values: tuple, lengths: Array):
    """Bulk-fill the rows of this cache view from a left-aligned prefill
    chunk, allocating exactly ceil(lengths / block_size) blocks per row.
    Any blocks the rows previously held are freed first (re-prefilling a
    live row cannot leak)."""
    cache = paged_reset_rows(cache, jnp.ones(cache.table.shape[:1], bool))
    pools = _paged_pools(cache)
    bs = pools[0].shape[1]
    p = pools[0].shape[0]
    b, m = cache.table.shape
    l = cache.pos_arr.shape[1]
    s = new_values[0].shape[1]
    # block j of row b is needed iff it holds any position < lengths[b]
    needs = (jnp.arange(m)[None, :] * bs) < lengths[:, None]     # [B, M]
    rank = (jnp.cumsum(needs.reshape(-1).astype(jnp.int32)) - 1).reshape(b, m)
    cand = _nth_free(cache.free, rank)
    got = needs & (cand < p)
    failed = cache.alloc_failed | jnp.any(needs & (cand >= p))
    free = cache.free.at[jnp.where(got, cand, p).reshape(-1)].set(
        False, mode="drop")
    table = jnp.where(got, cand, -1)
    # scatter the S chunk tokens (logical slot == absolute position)
    tok_slot = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    phys_blk = jnp.take_along_axis(table, tok_slot // bs, axis=1)
    can = (tok_slot < lengths[:, None]) & (phys_blk >= 0)
    flat = jnp.where(can, phys_blk * bs + tok_slot % bs, p * bs)
    pools = _scatter_tokens(pools, list(new_values), flat)
    idx = jnp.arange(l)[None, :]
    # a slot is valid only when its block allocation succeeded: an
    # unbacked-but-valid slot would gather block 0 (another request's
    # K/V) through paged_view's safe indexing
    backed = jnp.take_along_axis(table, idx // bs, axis=1) >= 0
    pos_arr = jnp.where((idx < lengths[:, None]) & backed, idx, -1)
    return _paged_replace(cache, pools, table=table, free=free,
                          pos_arr=pos_arr,
                          next_pos=lengths.astype(jnp.int32),
                          alloc_failed=failed)


def paged_rollback(cache, keep_pos: Array):
    """Invalidate slots holding positions >= keep_pos AND return the
    speculative-tail blocks (logical blocks past ceil(keep_pos / bs)) to
    the pool — the next chunk re-allocates as it grows."""
    bs = paged_block_size(cache)
    m = cache.table.shape[1]
    keep_blocks = -(-keep_pos // bs)                              # ceil
    drop = (jnp.arange(m)[None, :] >= keep_blocks[:, None]) \
        & (cache.table >= 0)
    p = cache.free.shape[0]
    free = cache.free.at[jnp.where(drop, cache.table, p).reshape(-1)].set(
        True, mode="drop")
    return cache._replace(
        table=jnp.where(drop, -1, cache.table), free=free,
        pos_arr=jnp.where(cache.pos_arr >= keep_pos[:, None], -1,
                          cache.pos_arr),
        next_pos=jnp.minimum(cache.next_pos, keep_pos))


def paged_reset_rows(cache, rows: Array):
    """Free ALL blocks of the selected rows (bool[B]) — request retirement.
    Unlike the static ``reset_rows``, the freed memory is immediately
    reusable by any other row."""
    p = cache.free.shape[0]
    sel = rows[:, None] & (cache.table >= 0)
    free = cache.free.at[jnp.where(sel, cache.table, p).reshape(-1)].set(
        True, mode="drop")
    return cache._replace(
        table=jnp.where(rows[:, None], -1, cache.table), free=free,
        pos_arr=jnp.where(rows[:, None], -1, cache.pos_arr),
        next_pos=jnp.where(rows, 0, cache.next_pos))


def paged_view(cache):
    """Gather the logical [B, L, ...] view of each pool through the block
    table (L = M * block_size).  Unallocated blocks read block 0; their
    slots are masked by ``pos_arr == -1`` so attention never sees them."""
    bs = paged_block_size(cache)
    b, m = cache.table.shape
    safe = jnp.maximum(cache.table, 0)
    out = []
    for pool in _paged_pools(cache):
        v = pool[safe]                                  # [B, M, bs, ...]
        out.append(v.reshape((b, m * bs) + pool.shape[2:]))
    return out


def paged_select_rows(cache, idx: Array):
    """Row-slice of the per-row state (table/pos_arr/next_pos); the pool
    and free list stay shared, so writes through the slice land in the
    same physical memory.  Inverse: ``paged_merge_rows``."""
    return cache._replace(table=cache.table[idx],
                          pos_arr=cache.pos_arr[idx],
                          next_pos=cache.next_pos[idx])


def paged_merge_rows(full, sub, idx: Array):
    """Merge a row-slice back: per-row state scatters into ``idx``; pool,
    free list and alloc flag come from the slice (they are the shared,
    already-updated allocator state)."""
    pools = _paged_pools(sub)
    return _paged_replace(
        full, pools,
        table=full.table.at[idx].set(sub.table),
        free=sub.free,
        pos_arr=full.pos_arr.at[idx].set(sub.pos_arr),
        next_pos=full.next_pos.at[idx].set(sub.next_pos),
        alloc_failed=sub.alloc_failed)


def paged_free_count(cache) -> Array:
    """Number of unallocated pool blocks (device scalar)."""
    return jnp.sum(cache.free.astype(jnp.int32))


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Host helper: blocks needed to hold n_tokens cache slots."""
    return -(-max(0, int(n_tokens)) // block_size)


def _write_one(values, pos_arr, next_pos, new_slices, ring):
    """Write one token (time index t of the chunk) into each value array.

    values: list of [B, L, ...]; new_slices: list of [B, ...] (no L axis).
    """
    l = pos_arr.shape[1]
    slot = next_pos % l if ring else jnp.minimum(next_pos, l - 1)
    hit = jnp.arange(l)[None, :] == slot[:, None]            # [B, L]
    out = []
    for val, new in zip(values, new_slices):
        mask = hit.reshape(hit.shape + (1,) * (val.ndim - 2))
        out.append(jnp.where(mask, new[:, None].astype(val.dtype), val))
    pos_arr = jnp.where(hit, next_pos[:, None], pos_arr)
    return out, pos_arr, next_pos + 1


def write_chunk(cache, new_values: tuple, chunk_valid: Array | None = None,
                ring: bool = False):
    """Append an S-token chunk.  new_values: tuple of [B, S, ...] arrays
    matching the cache's value fields.  chunk_valid: bool[B, S] marks real
    tokens (ragged verify batches); invalid steps don't advance the cache.

    Implemented as a fori over S masked writes — S is small on the
    decode/verify path (1..C tokens).  Prefill uses ``write_prefill``.
    """
    if isinstance(cache, PAGED_TYPES):
        return paged_write_chunk(cache, new_values, chunk_valid)
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    s = new_values[0].shape[1]

    def body(t, carry):
        vals, pos_arr, next_pos = carry
        slices = [nv[:, t] for nv in new_values]
        new_vals, new_pos_arr, new_next = _write_one(
            vals, pos_arr, next_pos, slices, ring)
        if chunk_valid is not None:
            ok = chunk_valid[:, t]
            new_vals = [jnp.where(ok.reshape((-1,) + (1,) * (v.ndim - 1)), nv, v)
                        for nv, v in zip(new_vals, vals)]
            new_pos_arr = jnp.where(ok[:, None], new_pos_arr, pos_arr)
            new_next = jnp.where(ok, new_next, next_pos)
        return new_vals, new_pos_arr, new_next

    vals, pos_arr, next_pos = jax.lax.fori_loop(
        0, s, body, (vals, cache.pos_arr, cache.next_pos))
    if is_mla:
        return cache._replace(ckv=vals[0], kpe=vals[1], pos_arr=pos_arr,
                              next_pos=next_pos)
    return cache._replace(k=vals[0], v=vals[1], pos_arr=pos_arr,
                          next_pos=next_pos)


def write_prefill(cache, new_values: tuple, lengths: Array,
                  ring: bool = False):
    """Bulk-fill an empty cache from a left-aligned prefill chunk.

    new_values: tuple of [B, S, ...] with S <= L; lengths: i32[B] valid
    prefix length per row.  For ring caches S may exceed the window — only
    the last ``window`` positions land (computed with a shifted write).
    """
    if isinstance(cache, PAGED_TYPES):
        return paged_write_prefill(cache, new_values, lengths)
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    b, l = cache.pos_arr.shape
    s = new_values[0].shape[1]
    idx = jnp.arange(l)[None, :]                              # [1, L]
    if not ring:
        assert s <= l, f"prefill chunk {s} exceeds cache {l}"
        out_vals = []
        for val, new in zip(vals, new_values):
            pad = jnp.zeros(val.shape[:1] + (l - s,) + val.shape[2:], val.dtype)
            full = jnp.concatenate([new.astype(val.dtype), pad], axis=1)
            out_vals.append(full)
        pos_arr = jnp.where(idx < lengths[:, None], idx, -1)
    else:
        # slot of absolute position p is p % L; gather source index per slot
        start = jnp.maximum(lengths - l, 0)                   # first kept pos
        # slot j holds absolute position p with p ≡ j (mod L), start<=p<len
        candidate = start[:, None] + (idx - start[:, None]) % l
        valid = candidate < lengths[:, None]
        src = jnp.clip(candidate, 0, s - 1)
        out_vals = []
        for val, new in zip(vals, new_values):
            sidx = src.reshape(b, l, *(1,) * (val.ndim - 2)).astype(jnp.int32)
            gathered = jnp.take_along_axis(new.astype(val.dtype), sidx, axis=1)
            out_vals.append(jnp.where(
                valid.reshape(b, l, *(1,) * (val.ndim - 2)), gathered, val))
        pos_arr = jnp.where(valid, candidate, -1)
    next_pos = lengths.astype(jnp.int32)
    if is_mla:
        return cache._replace(ckv=out_vals[0], kpe=out_vals[1],
                              pos_arr=pos_arr, next_pos=next_pos)
    return cache._replace(k=out_vals[0], v=out_vals[1], pos_arr=pos_arr,
                          next_pos=next_pos)


def rollback(cache, keep_pos: Array):
    """Speculative-decoding rollback: invalidate every slot holding an
    absolute position >= keep_pos[b] (rejected draft tokens).  Paged
    caches additionally return the freed tail blocks to the pool."""
    if isinstance(cache, PAGED_TYPES):
        return paged_over_groups(lambda c: paged_rollback(c, keep_pos),
                                 cache)
    drop = cache.pos_arr >= keep_pos[:, None]
    return cache._replace(pos_arr=jnp.where(drop, -1, cache.pos_arr),
                          next_pos=jnp.minimum(cache.next_pos, keep_pos))


def snapshot_alloc_flag(cache) -> Array | None:
    """Draft-tail snapshot for one-round-late rollback: the sticky
    ``alloc_failed`` flag BEFORE a speculative draft-ahead writes its
    tail.  Everything else the ahead-chunk touches is restored exactly by
    ``discard_tail`` (slot invalidation + tail-block free), but a pool
    allocation that failed only because of discarded ahead-writes must
    not poison the sticky flag — so the engine snapshots it at dispatch
    and ``discard_tail`` writes it back.  Returns a traced bool scalar
    (group 0 of stacked leaves; all groups share one allocator
    trajectory), or None for non-paged caches (nothing sticky to
    restore)."""
    if isinstance(cache, PAGED_TYPES):
        return cache.alloc_failed[0] if cache.next_pos.ndim == 2 \
            else cache.alloc_failed
    return None


def discard_tail(cache, keep_pos: Array, alloc_failed: Array | None = None):
    """One-round-late rollback of a speculative draft-ahead (overlap
    mode): identical to ``rollback`` — the ahead-tail's slots invalidate
    and its paged blocks return to the pool — except the sticky
    ``alloc_failed`` flag is restored to its pre-ahead snapshot
    (``snapshot_alloc_flag``).  With ``keep_pos = length +
    min(accepted+1, S)`` this lands the cache bit-exactly on the state a
    synchronous round would have produced: the deferred discard differs
    from the sync rollback only when the whole chunk was accepted, where
    it additionally drops the ahead-root's write at position length+S —
    a slot the synchronous round never wrote."""
    if isinstance(cache, PAGED_TYPES):
        def f(c):
            r = paged_rollback(c, keep_pos)
            if alloc_failed is not None:
                r = r._replace(alloc_failed=alloc_failed)
            return r
        return paged_over_groups(f, cache)
    return rollback(cache, keep_pos)


def reset_rows(cache, rows: Array):
    """Invalidate ALL slots of the selected rows (bool[B]) — used when a
    fresh request is admitted into a draft-server slot.  Stale K/V values
    stay in memory but are unreachable (pos_arr == -1 masks them); paged
    caches instead free the rows' blocks for immediate reuse."""
    if isinstance(cache, PAGED_TYPES):
        return paged_over_groups(lambda c: paged_reset_rows(c, rows),
                                 cache)
    return cache._replace(
        pos_arr=jnp.where(rows[:, None], -1, cache.pos_arr),
        next_pos=jnp.where(rows, 0, cache.next_pos))


def prefill_rows(cache, new_values: tuple, lengths: Array, rows: Array,
                 ring: bool = False):
    """Per-row re-prefill: rows where ``rows[b]`` is True are replaced by a
    fresh prefill of ``new_values``/``lengths`` (see ``write_prefill``);
    all other rows keep their existing contents untouched.  Single-cache
    primitive of the continuous-batching admission row-turnover; the
    serving engine applies the same row-select at the stack-cache level
    (``engine._merge_cache_rows``) since per-layer K/V is produced inside
    ``model.forward``."""
    fresh = write_prefill(reset_rows(cache, rows), new_values, lengths,
                          ring=ring)

    def sel(new, old):
        mask = rows.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(mask, new, old)

    return jax.tree.map(sel, fresh, cache)
