"""KV caches: full (static-length), sliding-window (ring buffer), MLA latent.

Layout [B, L, KV, hd] with the cache-length axis L second so it can be
sharded over the ``model`` mesh axis for decode (sequence-sharded
flash-decode; see DESIGN §5).  Every cache carries an explicit per-slot
absolute-position array (``pos_arr``, -1 = empty) so attention masks are
layout-independent — the same masking code covers left-aligned full caches
and wrapped ring buffers.

Chunk writes use masked broadcast selects rather than scatters: elementwise
on the sharded L axis, so GSPMD never needs to reshuffle the cache to write
one token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class AttnCache(NamedTuple):
    k: Array         # [B, L, KV, hd]
    v: Array         # [B, L, KV, hd]
    pos_arr: Array   # i32[B, L] absolute position stored in each slot, -1 empty
    next_pos: Array  # i32[B] next absolute position to write


class MLACache(NamedTuple):
    ckv: Array       # [B, L, r]     latent
    kpe: Array       # [B, L, rope]  decoupled rope key
    pos_arr: Array
    next_pos: Array


def init_attn_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                    dtype) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
    )


def init_mla_cache(batch: int, length: int, rank: int, rope_dim: int,
                   dtype) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, length, rank), dtype),
        kpe=jnp.zeros((batch, length, rope_dim), dtype),
        pos_arr=jnp.full((batch, length), -1, jnp.int32),
        next_pos=jnp.zeros((batch,), jnp.int32),
    )


def _write_one(values, pos_arr, next_pos, new_slices, ring):
    """Write one token (time index t of the chunk) into each value array.

    values: list of [B, L, ...]; new_slices: list of [B, ...] (no L axis).
    """
    l = pos_arr.shape[1]
    slot = next_pos % l if ring else jnp.minimum(next_pos, l - 1)
    hit = jnp.arange(l)[None, :] == slot[:, None]            # [B, L]
    out = []
    for val, new in zip(values, new_slices):
        mask = hit.reshape(hit.shape + (1,) * (val.ndim - 2))
        out.append(jnp.where(mask, new[:, None].astype(val.dtype), val))
    pos_arr = jnp.where(hit, next_pos[:, None], pos_arr)
    return out, pos_arr, next_pos + 1


def write_chunk(cache, new_values: tuple, chunk_valid: Array | None = None,
                ring: bool = False):
    """Append an S-token chunk.  new_values: tuple of [B, S, ...] arrays
    matching the cache's value fields.  chunk_valid: bool[B, S] marks real
    tokens (ragged verify batches); invalid steps don't advance the cache.

    Implemented as a fori over S masked writes — S is small on the
    decode/verify path (1..C tokens).  Prefill uses ``write_prefill``.
    """
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    s = new_values[0].shape[1]

    def body(t, carry):
        vals, pos_arr, next_pos = carry
        slices = [nv[:, t] for nv in new_values]
        new_vals, new_pos_arr, new_next = _write_one(
            vals, pos_arr, next_pos, slices, ring)
        if chunk_valid is not None:
            ok = chunk_valid[:, t]
            new_vals = [jnp.where(ok.reshape((-1,) + (1,) * (v.ndim - 1)), nv, v)
                        for nv, v in zip(new_vals, vals)]
            new_pos_arr = jnp.where(ok[:, None], new_pos_arr, pos_arr)
            new_next = jnp.where(ok, new_next, next_pos)
        return new_vals, new_pos_arr, new_next

    vals, pos_arr, next_pos = jax.lax.fori_loop(
        0, s, body, (vals, cache.pos_arr, cache.next_pos))
    if is_mla:
        return cache._replace(ckv=vals[0], kpe=vals[1], pos_arr=pos_arr,
                              next_pos=next_pos)
    return cache._replace(k=vals[0], v=vals[1], pos_arr=pos_arr,
                          next_pos=next_pos)


def write_prefill(cache, new_values: tuple, lengths: Array,
                  ring: bool = False):
    """Bulk-fill an empty cache from a left-aligned prefill chunk.

    new_values: tuple of [B, S, ...] with S <= L; lengths: i32[B] valid
    prefix length per row.  For ring caches S may exceed the window — only
    the last ``window`` positions land (computed with a shifted write).
    """
    is_mla = isinstance(cache, MLACache)
    vals = [cache.ckv, cache.kpe] if is_mla else [cache.k, cache.v]
    b, l = cache.pos_arr.shape
    s = new_values[0].shape[1]
    idx = jnp.arange(l)[None, :]                              # [1, L]
    if not ring:
        assert s <= l, f"prefill chunk {s} exceeds cache {l}"
        out_vals = []
        for val, new in zip(vals, new_values):
            pad = jnp.zeros(val.shape[:1] + (l - s,) + val.shape[2:], val.dtype)
            full = jnp.concatenate([new.astype(val.dtype), pad], axis=1)
            out_vals.append(full)
        pos_arr = jnp.where(idx < lengths[:, None], idx, -1)
    else:
        # slot of absolute position p is p % L; gather source index per slot
        start = jnp.maximum(lengths - l, 0)                   # first kept pos
        # slot j holds absolute position p with p ≡ j (mod L), start<=p<len
        candidate = start[:, None] + (idx - start[:, None]) % l
        valid = candidate < lengths[:, None]
        src = jnp.clip(candidate, 0, s - 1)
        out_vals = []
        for val, new in zip(vals, new_values):
            sidx = src.reshape(b, l, *(1,) * (val.ndim - 2)).astype(jnp.int32)
            gathered = jnp.take_along_axis(new.astype(val.dtype), sidx, axis=1)
            out_vals.append(jnp.where(
                valid.reshape(b, l, *(1,) * (val.ndim - 2)), gathered, val))
        pos_arr = jnp.where(valid, candidate, -1)
    next_pos = lengths.astype(jnp.int32)
    if is_mla:
        return cache._replace(ckv=out_vals[0], kpe=out_vals[1],
                              pos_arr=pos_arr, next_pos=next_pos)
    return cache._replace(k=out_vals[0], v=out_vals[1], pos_arr=pos_arr,
                          next_pos=next_pos)


def rollback(cache, keep_pos: Array):
    """Speculative-decoding rollback: invalidate every slot holding an
    absolute position >= keep_pos[b] (rejected draft tokens)."""
    drop = cache.pos_arr >= keep_pos[:, None]
    return cache._replace(pos_arr=jnp.where(drop, -1, cache.pos_arr),
                          next_pos=jnp.minimum(cache.next_pos, keep_pos))


def reset_rows(cache, rows: Array):
    """Invalidate ALL slots of the selected rows (bool[B]) — used when a
    fresh request is admitted into a draft-server slot.  Stale K/V values
    stay in memory but are unreachable (pos_arr == -1 masks them)."""
    return cache._replace(
        pos_arr=jnp.where(rows[:, None], -1, cache.pos_arr),
        next_pos=jnp.where(rows, 0, cache.next_pos))


def prefill_rows(cache, new_values: tuple, lengths: Array, rows: Array,
                 ring: bool = False):
    """Per-row re-prefill: rows where ``rows[b]`` is True are replaced by a
    fresh prefill of ``new_values``/``lengths`` (see ``write_prefill``);
    all other rows keep their existing contents untouched.  Single-cache
    primitive of the continuous-batching admission row-turnover; the
    serving engine applies the same row-select at the stack-cache level
    (``engine._merge_cache_rows``) since per-layer K/V is produced inside
    ``model.forward``."""
    fresh = write_prefill(reset_rows(cache, rows), new_values, lengths,
                          ring=ring)

    def sel(new, old):
        mask = rows.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(mask, new, old)

    return jax.tree.map(sel, fresh, cache)
