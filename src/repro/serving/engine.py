"""The GoodSpeed serving engine: N draft servers + 1 verification server,
with REAL transformer models end-to-end (Algorithm 1 over actual logits).

Round structure (paper Fig. 1):
  (0) GOODSPEED-SCHED allocates S(t) from the current estimates, with each
      server's remaining-request cap fed in as its per-server s_max
      (completion-aware allocation; idle servers get zero budget and are
      masked out of the verify chunk entirely);
  (1) each draft server autoregressively samples S_i(t) tokens from its
      draft model (KV-cached decode steps);
  (2-3) drafts are batched into one ragged [N*R, S_max] verify batch
        (R = draft lanes: concurrent request rows per server);
  (4) the target model scores the chunk [pending_i, d_1..d_S] in ONE
      decode-chunk forward (positions len_i..len_i+S), and the verifier
      runs lossless rejection sampling (core.speculative.verify);
  (5) estimators update (Eqs. 3-4);
  (6) accepted tokens commit; caches roll back past rejected drafts.

The round is an explicit ROUND GRAPH of pure phase functions —
``_draft_phase`` (plan budgets + draft decode) -> ``_verify_phase``
(ragged chunk + rejection sampling) -> ``_reconcile_phase``
(commit/rollback + estimator + latency) — coordinated by a small
pure-Python ``RoundPlan``.  With ``overlap=False`` (default) the phases
compose inside ONE jit-compiled function with the engine state donated,
so the dynamic serving loop pays no per-round retrace or cache-copy cost
and emits byte-identical sequences to the historical monolithic round.
With ``overlap=True`` the phases compile separately (donated caches) and
the engine additionally dispatches a speculative DRAFT-AHEAD for round
t+1 — continuing from the round-t draft tail over the post-draft cache
buffer, budgeted from round t-1's estimator observations (the update
lands one round late relative to the speculative dispatch) — before
round t's verification is reconciled; the reconcile then applies a
one-round-late ``kv_cache.discard_tail`` that provably restores the
draft cache to the exact synchronous post-round state, so overlap
changes WHEN work runs, never WHAT is accepted (tests/test_overlap.py).
``attn_backend="kernel"`` additionally routes every attention in the
round — draft decode, the verify chunk, and the jit'd admission prefill —
through the Pallas kernel packages (``repro.kernels``: flash_prefill /
flash_decode / paged_flash_decode, with spec_verify's fused
gather-logprobs behind ``core.speculative.verify``); ``"jnp"`` keeps the
blockwise jnp core.  Both backends emit identical accepted-token
sequences (tests/test_paged_kernel.py).

Request lifecycle (``serve_requests``): the verification server owns a
``RequestManager`` (serving.request) with ONE global arrival queue; a
pluggable placement policy (``placement="static" | "jsq" | "goodput"``,
serving.placement) routes each arrival onto a draft server at admission
time, deciding against the live estimator state (alpha_hat), per-server
queue loads, and free paged-KV blocks.  Each server carries up to
``lanes`` ACTIVE requests, one per draft lane — the batch axis is
[N*R] lane rows, server-major — and when a request completes
(per-request cap reached or EOS emitted) the next queued request is
seated into the freed lane immediately: continuous batching at lane
granularity.  GOODSPEED-SCHED keeps allocating per SERVER (the paper's
fairness unit; alpha_hat / X^beta stay f32[N]) and
``core.scheduler.split_lanes`` water-fills each server's budget across
its live lanes by remaining caps.  Admission re-prefills ONLY the
fresh rows of both model caches — ``_admit_rows`` runs a full-batch prefill
and row-merges it into the live stack caches (``_merge_cache_rows``, the
stack-level analogue of the single-cache ``kv_cache.prefill_rows``) while
the neighbouring rows keep decoding — and ``remaining_caps()`` flows into
the scheduler every round so budget never lands on finished work.

Cache-consistency invariant: a model's cache always contains the committed
sequence EXCEPT the final committed token, which is the next chunk's first
input ("pending").  Rollback strategies:
  * attention/MLA caches — slot invalidation (kv_cache.rollback), O(1);
  * recurrent states (SSM/hybrid) — checkpoint-and-recompute: the engine
    snapshots the state before the chunk and, after verification, re-runs
    the accepted prefix only.  ``Rollback=recompute`` is correct for every
    architecture; slot rollback is the fast path for pure-attention stacks.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.budget import verify_bucket
from repro.core.estimator import EstimatorState, GoodputEstimator
from repro.core.latency import LatencyModel
from repro.core.scheduler import fixed_s, make_scheduler, plan_budgets
from repro.core.speculative import verify
from repro.core.utility import UtilitySpec
from repro.models import Model
from repro.serving.faults import FaultPlan, HealthTracker, RoundFaults
from repro.serving.guards import TraceGuard
from repro.serving.kv_cache import (AttnCache, CacheOverflowError, MLACache,
                                    PAGED_TYPES, PoolExhaustedError,
                                    StickyFlags, blocks_for, discard_tail,
                                    paged_merge_rows, paged_over_groups,
                                    paged_reset_rows, paged_select_rows,
                                    reset_rows, rollback,
                                    snapshot_sticky_flags)
from repro.serving.placement import PlacementView, make_placement
from repro.serving.prefix import PrefixIndex
from repro.serving.request import Request, RequestManager

Array = jnp.ndarray


def _is_rollbackable(cfg: ModelConfig) -> bool:
    """Slot rollback works for full-attention stacks (incl. MLA).  Ring
    buffers overwrite old slots during the chunk and recurrent states are
    not invertible — those use checkpoint-and-recompute."""
    return set(cfg.layer_kinds) <= {"attn"}


_ROLLBACK_TYPES = (AttnCache, MLACache) + PAGED_TYPES


def _cache_rollback(cache, keep_pos: Array):
    """Slot-invalidate every attention cache in the stack cache pytree.
    Paged caches additionally return speculative-tail blocks to the pool
    (``kv_cache.paged_rollback``)."""
    def fix(c):
        if isinstance(c, _ROLLBACK_TYPES):
            return rollback(c, keep_pos)
        return c
    return jax.tree.map(fix, cache,
                        is_leaf=lambda c: isinstance(c, _ROLLBACK_TYPES))


def _stack_sticky_flags(cache) -> StickyFlags:
    """Traced sticky-flag snapshot (``alloc_failed`` + per-row
    ``overflowed``) of a stack cache's first attention leaf — the
    draft-tail snapshot the one-round-late discard restores
    (``kv_cache.snapshot_sticky_flags``).  One leaf is representative:
    overlap mode asserts pure-attention stacks, where every leaf follows
    the identical write trajectory."""
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda c: isinstance(c, _ROLLBACK_TYPES)):
        if isinstance(leaf, _ROLLBACK_TYPES):
            return snapshot_sticky_flags(leaf)
    return StickyFlags(alloc_failed=None, overflowed=jnp.zeros((0,), bool))


def _cache_discard_tail(cache, keep_pos: Array, flags: StickyFlags):
    """One-round-late rollback of the whole stack cache: every attention
    leaf discards slots >= keep_pos (``kv_cache.discard_tail``) and
    restores the pre-ahead sticky snapshots — a pool exhaustion or row
    overflow caused only by discarded ahead-writes must not poison the
    sticky health flags."""
    def fix(c):
        if isinstance(c, PAGED_TYPES):
            return discard_tail(c, keep_pos, flags.alloc_failed,
                                flags.overflowed)
        if isinstance(c, _ROLLBACK_TYPES):
            return discard_tail(c, keep_pos, overflowed=flags.overflowed)
        return c
    return jax.tree.map(fix, cache,
                        is_leaf=lambda c: isinstance(c, _ROLLBACK_TYPES))


def _first_paged_leaf(cache):
    """First paged cache leaf of a stack cache (None if the stack has no
    full-attention layers or runs static caches).  All paged leaves share
    one deterministic allocator trajectory, so one leaf is representative.
    Scan-group leaves carry a leading layer-group axis; return group 0.
    Diagnostics/tests only — it slices the full pools; the serving loop
    uses ``_paged_alloc_state``."""
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda c: isinstance(c, PAGED_TYPES)):
        if isinstance(leaf, PAGED_TYPES):
            if leaf.next_pos.ndim == 2:
                return jax.tree.map(lambda a: a[0], leaf)
            return leaf
    return None


def _paged_alloc_state(cache):
    """(block_size, free bool[P], alloc_failed scalar) of the first paged
    leaf, touching only the small allocator fields (never the pools) —
    cheap enough for every-round health checks.  None if unpaged."""
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda c: isinstance(c, PAGED_TYPES)):
        if isinstance(leaf, PAGED_TYPES):
            stacked = leaf.next_pos.ndim == 2
            pool = leaf.kpool if hasattr(leaf, "kpool") else leaf.ckv_pool
            bs = pool.shape[2] if stacked else pool.shape[1]
            return (bs, leaf.free[0] if stacked else leaf.free,
                    leaf.alloc_failed[0] if stacked else leaf.alloc_failed)
    return None


def _paged_host_fields(cache):
    """Host (numpy) copies of the first paged leaf's small per-row fields:
    ``(block_size, table i32[B, M], refcount i32[P], overflowed bool[B])``
    — never the pool buffers.  Backs every-round block accounting, the
    overflow health check and the prefix-index bookkeeping.  None if the
    stack is unpaged."""
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda c: isinstance(c, PAGED_TYPES)):
        if isinstance(leaf, PAGED_TYPES):
            stacked = leaf.next_pos.ndim == 2
            pool = leaf.kpool if hasattr(leaf, "kpool") else leaf.ckv_pool
            bs = pool.shape[2] if stacked else pool.shape[1]
            sel = (lambda a: a[0]) if stacked else (lambda a: a)
            return (bs, np.asarray(sel(leaf.table)),
                    np.asarray(sel(leaf.refcount)),
                    np.asarray(sel(leaf.overflowed)))
    return None


def _stack_overflow_rows(cache):
    """bool[B] OR of every attention leaf's sticky ``overflowed`` flag (a
    ring leaf never sets it; full-attention leaves share one trajectory,
    but OR-ing is correct for any mix).  None when the stack has no
    attention caches."""
    acc = None
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda c: isinstance(c, _ROLLBACK_TYPES)):
        if isinstance(leaf, _ROLLBACK_TYPES):
            o = leaf.overflowed
            if o.ndim == 2:                 # scan-group stacking [G, B]
                o = o.any(axis=0)
            acc = o if acc is None else acc | o
    return None if acc is None else np.asarray(acc)




def _merge_cache_rows(old, new, rows: Array):
    """Row-select between two stack caches of identical structure: rows
    where ``rows[b]`` take the fresh cache, others keep the old one.
    Scan-group subtrees stack a leading layer-group axis, so batch sits at
    axis 1 there and at axis 0 in the "rest" subtree.  (This is the
    stack-level analogue of ``kv_cache.prefill_rows``.)"""
    def sel(axis):
        def f(o, n_):
            m = rows.reshape((1,) * axis + (-1,) + (1,) * (o.ndim - axis - 1))
            return jnp.where(m, n_, o)
        return f
    return {"scan": jax.tree.map(sel(1), old["scan"], new["scan"]),
            "rest": jax.tree.map(sel(0), old["rest"], new["rest"])}


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Pure-Python coordinator of one round of the round graph: the HOST
    inputs every phase dispatch shares.  The per-lane budgets S and the
    active mask derive from ``caps`` on device inside ``_draft_phase``
    (via ``core.scheduler.plan_budgets``) so planning never forces a
    host sync on the estimator state."""
    caps: np.ndarray          # i32[N*R] per-lane remaining budgets (host)
    s_bucket: int             # jit-static speculative chunk bucket
    overlap: bool             # dispatch a round-(t+1) draft-ahead
    admitted: tuple = ()      # rows re-prefilled just before this round


class DraftOut(NamedTuple):
    """Device outputs of ``draft_dispatch`` (phase 1 of the round graph)."""
    toks: Array       # i32[N*R, s_max] drafted tokens
    qlogits: Array    # f32[N*R, s_max, V] draft sampling distributions
    S: Array          # i32[N*R] per-lane budgets (device-planned)
    active: Array     # bool[N*R]
    cache: object     # post-scan draft stack cache
    k_verify: Array   # subkey for rejection sampling
    k_jit: Array      # subkey for the latency jitter draw
    key: Array        # next round's state key


class VerifyOut(NamedTuple):
    """Device outputs of ``verify_dispatch`` (phase 2 of the round graph)."""
    cache: object       # post-chunk target stack cache
    accepted: Array     # i32[N*R] m (idle rows masked to 0)
    num_emitted: Array  # i32[N*R] m + 1 for active rows
    extra_token: Array  # i32[N*R] residual/bonus token
    emitted: Array      # i32[N*R, s_max+1], -1 padded
    ratio_sum: Array    # f32[N*R] Eq.-3 accept-ratio sums


class EngineState(NamedTuple):
    # sequences: committed tokens per lane row (host-side ragged
    # bookkeeping).  All row-indexed arrays are [N*R], server-major: row
    # b serves (server b // R, lane b % R); estimator state stays [N].
    target_cache: object
    draft_cache: object
    pending: Array        # i32[N*R] last committed token (next chunk input)
    length: Array         # i32[N*R] committed length EXCLUDING pending
    est: EstimatorState   # per-SERVER (alpha_hat/goodput: f32[N])
    S: Array              # i32[N*R] per-lane allocation used last round
    key: Array


class RoundStats(NamedTuple):
    S: np.ndarray          # i32[N*R] per-lane draft lengths (server-major)
    accepted: np.ndarray   # i32[N*R]
    realized: np.ndarray   # f32[N*R]
    alpha_hat: np.ndarray  # f32[N] per-server (the fairness unit)
    goodput_est: np.ndarray  # f32[N]
    utility: float
    wall: np.ndarray       # [total, receive, verify, send]
    emitted: np.ndarray    # [N*R, S_max+1] tokens, -1 padded
    # overlapped-round simulated wall time: max(receive_t, verify_{t-1})
    # + send (LatencyModel.overlapped_round_time).  == wall[0] when the
    # engine runs synchronously (overlap=False).
    wall_overlap: float = 0.0
    # i32[N*R] speculative draft-ahead budgets dispatched for round t+1
    # (zeros when overlap=False)
    ahead_S: np.ndarray = None
    # bool[N] per-SERVER verify-deadline misses this round (chunk arrived
    # past RoundFaults.deadline or was dropped): the server's speculative
    # tokens were discarded — zero accepted, no bonus, caches rolled back.
    # All-False without a fault plan.  Feeds HealthTracker.observe_round.
    missed: np.ndarray = None
    # f32[N] simulated per-server chunk arrival times (diagnostics)
    arrival: np.ndarray = None


@dataclasses.dataclass(frozen=True)
class GoodSpeedEngine:
    draft_model: Model
    target_model: Model
    n_servers: int
    C: int
    s_max: int                     # per-lane draft cap (latency bound)
    cache_len: int = 512
    # draft lanes: concurrent request slots PER SERVER.  Every row-indexed
    # surface (caches, pending/length, caps, verify chunk) runs at batch
    # N*R, server-major; GOODSPEED-SCHED still allocates per SERVER (the
    # paper's fairness unit, alpha_hat/X^beta stay f32[N]) and
    # ``core.scheduler.split_lanes`` water-fills each server's S_i across
    # its live lanes.  lanes=1 is byte-identical to the single-request
    # engine (tests/test_lanes.py pins it against a recorded trace).
    lanes: int = 1
    policy: str = "goodspeed"      # goodspeed | greedy | fixed | random
    estimator: GoodputEstimator = GoodputEstimator()
    utility: UtilitySpec = UtilitySpec(alpha=1.0)
    latency: LatencyModel = LatencyModel()
    draft_temps: tuple = ()        # per-server draft temperature (heterogeneity)
    # paged (block-pool) KV caches: admission allocates per-row blocks and
    # prefills ONLY the admitted rows (batch = #admitted, not n_servers);
    # retirement/rollback return blocks to the pool.  False keeps the
    # static [B, L] caches so both paths can be diffed for equivalence.
    paged_kv: bool = False
    kv_block_size: int = 16
    kv_num_blocks: int = 0         # 0 = n_rows * ceil(cache_len / bs)
    # request placement at admission ("static" | "jsq" | "goodput", or a
    # PlacementPolicy instance): how serve_requests routes arrivals onto
    # draft servers.  "static" keeps the submitted per-server affinity
    # (the equivalence baseline); "jsq" joins the shortest queue;
    # "goodput" places against live alpha_hat estimates and paged-KV
    # block pressure (repro.serving.placement).
    placement: str = "static"
    # attention/verify backend, ONE flag for the whole hot path: "kernel"
    # rebuilds both models with cfg.attn_backend="kernel" (draft decode,
    # verify chunk and the jit'd admission prefill dispatch to the Pallas
    # kernel packages — paged_flash_decode / flash_decode / flash_prefill
    # — with jnp fallbacks wherever a kernel doesn't apply) and routes
    # rejection sampling through the fused spec_verify gather-logprobs
    # kernel.  None inherits the target model's cfg.attn_backend.
    attn_backend: Optional[str] = None
    # double-buffered draft/verify overlap (the round-graph payoff): each
    # round additionally dispatches a speculative draft-ahead for round
    # t+1 from the current draft tail while round t's verify chunk is in
    # flight, and reconciliation lands one round late, discarding the
    # ahead tail exactly (kv_cache.discard_tail) whenever verification
    # rejects its root.  Accepted-token sequences are IDENTICAL to
    # overlap=False; the win is the simulated overlapped round time
    # (max(draft_{t+1}, verify_t) + send) plus host/device pipelining —
    # all four phase dispatches enqueue before any host sync.  Requires
    # slot-rollbackable (pure-attention) stacks for both models: a
    # ring/recurrent draft state cannot undo the ahead writes.
    overlap: bool = False
    # deterministic greedy speculative decoding: drafts take the draft
    # model's argmax, verification accepts a draft token iff it equals
    # the target argmax, and the extra token is the target argmax at the
    # first mismatch (core.speculative.verify(greedy=True)).  The emitted
    # sequence is exactly the target's greedy decode — a pure function of
    # the committed context, independent of batch row / round boundaries
    # / rng — which makes request migration byte-equivalent to an
    # uninterrupted run (the churn property tests pin this).
    greedy: bool = False
    # vLLM-style prefix caching (requires paged_kv + pure-attention
    # stacks): a host-side per-model content index maps each FULL
    # block_size-token prompt-prefix block to the live pool block already
    # holding its K/V, so admission ATTACHES the shared prefix (refcount
    # bump, zero prefill compute) and prefills only each request's unique
    # suffix — admission cost scales with the unique-suffix length
    # instead of the full prompt.  Accepted tokens are identical to
    # prefix_cache=False (the shared blocks hold bitwise the same K/V the
    # row's own prefill would have written); OFF by default so every
    # recorded golden trace stays byte-identical.
    prefix_cache: bool = False

    def __post_init__(self):
        # serving-surface validation: misconfigurations fail HERE with a
        # clear ValueError, not rounds later as shape errors inside jit
        if self.lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {self.lanes}")
        # resolve the policy once; validates the name at construction time
        object.__setattr__(self, "_sched", make_scheduler(self.policy))
        make_placement(self.placement)   # validate at construction time
        backend = self.attn_backend
        if backend is None:
            backend = self.target_model.cfg.attn_backend
            object.__setattr__(self, "attn_backend", backend)
        if backend not in ("jnp", "kernel"):
            raise ValueError(f"attn_backend must be 'jnp' or 'kernel', "
                             f"got {backend!r}")
        for name in ("draft_model", "target_model"):
            model = getattr(self, name)
            if model.cfg.attn_backend != backend:
                object.__setattr__(self, name, Model(dataclasses.replace(
                    model.cfg, attn_backend=backend)))
        # Speculative chunk shapes snap to the canonical bucket table
        # (core.budget.VERIFY_BUCKETS); the REAL draft/verify shapes stay
        # at the exact s_max (recorded equivalence traces pin them).
        object.__setattr__(self, "s_bucket", verify_bucket(self.s_max))
        if self.overlap:
            assert _is_rollbackable(self.draft_model.cfg) and \
                _is_rollbackable(self.target_model.cfg), \
                ("overlap=True needs slot-rollbackable (pure-attention) "
                 "stacks for both models: the one-round-late discard "
                 "cannot undo ahead-writes into ring/recurrent state")
        # overlap=False: the phases compose inside ONE compiled round with
        # the engine state donated so caches update in place — the dynamic
        # serve loop stays retrace-free and byte-identical to the
        # historical monolithic round.
        object.__setattr__(self, "_round_fn",
                           jax.jit(self._round_core, donate_argnums=(0,)))
        # overlap=True: separately compiled, donated-cache phase dispatches
        # (draft -> verify -> draft-ahead -> deferred reconcile).  jax.jit
        # is lazy, so these cost nothing unless the overlap path runs.
        object.__setattr__(self, "_draft_fn",
                           jax.jit(self._draft_phase, donate_argnums=(1,)))
        object.__setattr__(self, "_verify_fn",
                           jax.jit(self._verify_phase, donate_argnums=(1,)))
        object.__setattr__(self, "_ahead_fn",
                           jax.jit(self._ahead_phase, donate_argnums=(1,)))
        object.__setattr__(self, "_reconcile_fn",
                           jax.jit(self._reconcile_overlap,
                                   donate_argnums=(2, 3)))
        # jit-compiled admission prefill per model, with the cache donated
        # so paged admission updates the shared pools in place instead of
        # copying them per admission.  Retraces per distinct
        # (batch, maxlen) admission shape — bounded in steady-state
        # serving, and what makes admission cost ~independent of the
        # total batch under paged_kv (benchmarks/serve_requests.py).
        def _make_prefill(model):
            def f(params, toks, cache, chunk_valid):
                return model.forward(params, toks, mode="prefill",
                                     cache=cache, chunk_valid=chunk_valid)
            return jax.jit(f, donate_argnums=(2,))
        object.__setattr__(self, "_prefill_fn_target",
                           _make_prefill(self.target_model))
        object.__setattr__(self, "_prefill_fn_draft",
                           _make_prefill(self.draft_model))
        # prefix caching: shared-suffix admission prefill — the chunk
        # holds only each row's unique suffix at explicit absolute
        # positions, with the shared prompt prefix attached by physical
        # block id (kv_cache.paged_write_prefill).  Separate jits from
        # the plain prefill so the feature-off path never retraces.
        if self.prefix_cache:
            if not self.paged_kv:
                raise ValueError("prefix_cache=True requires paged_kv=True"
                                 " (sharing lives in the block pool)")
            if not (_is_rollbackable(self.draft_model.cfg)
                    and _is_rollbackable(self.target_model.cfg)):
                raise ValueError(
                    "prefix_cache=True requires pure-attention stacks for "
                    "both models: ring/recurrent layers hold state outside "
                    "the paged pool, so an attached prefix would be "
                    "invisible to them")

        def _make_prefill_shared(model):
            def f(params, toks, cache, chunk_valid, positions,
                  shared_blocks, shared_lens):
                return model.forward(params, toks, mode="prefill",
                                     cache=cache, chunk_valid=chunk_valid,
                                     positions=positions,
                                     shared_blocks=shared_blocks,
                                     shared_lens=shared_lens)
            return jax.jit(f, donate_argnums=(2,))
        object.__setattr__(self, "_prefill_shared_fn_target",
                           _make_prefill_shared(self.target_model))
        object.__setattr__(self, "_prefill_shared_fn_draft",
                           _make_prefill_shared(self.draft_model))
        # host-side content index per MODEL (draft and target pools hold
        # different K/V and follow different allocation trajectories)
        object.__setattr__(self, "_prefix_index",
                           {"target": PrefixIndex(), "draft": PrefixIndex()})

    @property
    def n_rows(self) -> int:
        """Total lane rows: n_servers * lanes (the batch axis)."""
        return self.n_servers * self.lanes

    # ------------------------------------------------------------------
    def _fresh_cache(self, model: Model, batch: int):
        """Empty stack cache in the engine's configured layout."""
        return model.init_cache(batch, self.cache_len,
                                ring_headroom=self.s_max,
                                paged=self.paged_kv,
                                block_size=self.kv_block_size,
                                num_blocks=self.kv_num_blocks)

    # ------------------------------------------------------------------
    def _prefill_rows(self, prompts: list[np.ndarray], draft_params,
                      target_params):
        """Prefill FRESH caches for the given per-row prompts; returns
        (target_cache, draft_cache, pending, length)."""
        n = self.n_rows
        assert len(prompts) == n
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((n, maxlen), np.int32)
        valid = np.zeros((n, maxlen), bool)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            valid[i, :len(p)] = True
        toks_j = jnp.asarray(toks)
        valid_j = jnp.asarray(valid)
        lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)

        # Prefill caches with all but the LAST prompt token of each row:
        # feeding token t writes slot t; "pending" = last prompt token.
        pend_idx = jnp.maximum(lengths - 1, 0)
        feed_valid = valid_j & (jnp.arange(maxlen)[None, :] < pend_idx[:, None])
        # Ring (sliding-window) layers need chunk_len-1 slots of headroom:
        # the verify/recompute chunks are s_max+1 tokens, written before
        # attention runs (see init_block_cache).  NOTE: this is the STATIC
        # full-batch prefill path; paged engines admit via
        # ``_admit_rows_paged`` (sub-batch prefill into the shared pool).
        tcache = self.target_model.init_cache(n, self.cache_len,
                                              ring_headroom=self.s_max)
        dcache = self.draft_model.init_cache(n, self.cache_len,
                                             ring_headroom=self.s_max)
        t_out = self._prefill_fn_target(target_params, toks_j, tcache,
                                        feed_valid)
        d_out = self._prefill_fn_draft(draft_params, toks_j, dcache,
                                       feed_valid)
        pending = jnp.take_along_axis(toks_j, pend_idx[:, None], axis=1)[:, 0]
        return t_out.cache, d_out.cache, pending, pend_idx

    def init(self, key: Array, prompts: list[np.ndarray],
             draft_params, target_params) -> EngineState:
        """Prefill both models on the per-row prompts (one prompt per lane
        row, server-major — n_servers * lanes entries)."""
        if self.paged_kv:
            state = self.cold_start(key)
            return self._admit_rows(
                state, list(range(self.n_rows)),
                dict(enumerate(prompts)), draft_params, target_params)
        tcache, dcache, pending, length = self._prefill_rows(
            prompts, draft_params, target_params)
        return EngineState(
            target_cache=tcache, draft_cache=dcache,
            pending=pending, length=length,
            est=self.estimator.init(self.n_servers),
            S=fixed_s(self.n_rows, self.C), key=key)

    def cold_start(self, key: Array) -> EngineState:
        """All-idle engine state with empty caches — no model forward.
        ``serve_requests`` starts here: every row is masked out until its
        first admission re-prefills it, so prefilling dummy prompts would
        be wasted compute."""
        for index in self._prefix_index.values():
            index.clear()                  # fresh pools: no live blocks
        b = self.n_rows
        return EngineState(
            target_cache=self._fresh_cache(self.target_model, b),
            draft_cache=self._fresh_cache(self.draft_model, b),
            pending=jnp.zeros((b,), jnp.int32),
            length=jnp.zeros((b,), jnp.int32),
            est=self.estimator.init(self.n_servers),
            S=fixed_s(b, self.C), key=key)

    # ------------------------------------------------------------------
    def _admit_rows(self, state: EngineState, rows: list[int],
                    prompts: dict, draft_params, target_params,
                    budgets: Optional[dict] = None) -> EngineState:
        """Continuous-batching admission: re-prefill ONLY the cache rows in
        ``rows`` with their new request prompts; every other row's cache,
        pending token and length are untouched.  Estimator state persists —
        alpha_hat / X^beta track the draft SERVER, not the request.

        budgets: optional per-row generation budget; when either model
        keeps a full (non-ring) attention cache, admission fails loudly if
        prompt + budget + 1 (bonus token) cannot fit in cache_len —
        ``write_chunk`` would otherwise silently clobber the last slot.
        Ring/recurrent-only stacks are O(window) and carry no such bound.

        With ``paged_kv`` the admission prefill runs at batch = len(rows)
        and scatters straight into the shared block pools
        (``_admit_rows_paged``) — cost independent of the total rows."""
        n = self.n_rows
        self._check_admission_fits(
            [np.asarray(prompts[i], np.int32) for i in rows], rows, budgets)
        if self.paged_kv:
            return self._admit_rows_paged(state, rows, prompts,
                                          draft_params, target_params)
        mask = np.zeros((n,), bool)
        mask[list(rows)] = True
        row_prompts = [np.asarray(prompts[i], np.int32) if mask[i]
                       else np.zeros(1, np.int32) for i in range(n)]
        tcache, dcache, pending, length = self._prefill_rows(
            row_prompts, draft_params, target_params)
        mask_j = jnp.asarray(mask)
        return state._replace(
            target_cache=_merge_cache_rows(state.target_cache, tcache, mask_j),
            draft_cache=_merge_cache_rows(state.draft_cache, dcache, mask_j),
            pending=jnp.where(mask_j, pending, state.pending),
            length=jnp.where(mask_j, length, state.length))

    def _check_admission_fits(self, row_prompts, rows, budgets):
        """Per-row logical-capacity guard shared by both admission paths."""
        bounded = any(k == "attn" for m in (self.draft_model,
                                            self.target_model)
                      for k in m.cfg.layer_kinds)
        for i, p in zip(rows, row_prompts):
            need = len(p) + (budgets or {}).get(i, 0) + 1
            assert not bounded or need <= self.cache_len, \
                (f"request needs {need} cache slots (prompt "
                 f"{len(p)} + budget {(budgets or {}).get(i, 0)}"
                 f" + bonus) but cache_len is {self.cache_len}")

    # ------------------------------------------------------------------
    def _admit_slice(self, model: Model, cache, idx: Array, k: int):
        """Admission view of the live stack cache at batch = k: paged
        leaves are row-sliced (shared pool) with the rows' old blocks
        freed; every other leaf (ring buffers, recurrent states) starts
        from a fresh zero state for those rows."""
        # num_blocks=1 keeps the throwaway paged leaves tiny: only the
        # ring/recurrent leaves of this fresh cache are used, the paged
        # ones are replaced by live-pool slices below
        fresh = model.init_cache(k, self.cache_len,
                                 ring_headroom=self.s_max,
                                 paged=self.paged_kv,
                                 block_size=self.kv_block_size,
                                 num_blocks=1)
        all_rows = jnp.ones((k,), bool)

        def f(fr, live):
            if isinstance(live, PAGED_TYPES):
                return paged_over_groups(
                    lambda c: paged_reset_rows(paged_select_rows(c, idx),
                                               all_rows), live)
            return fr
        return jax.tree.map(f, fresh, cache,
                            is_leaf=lambda c: isinstance(c, PAGED_TYPES))

    def _merge_admit(self, cache, sub, idx: Array):
        """Merge an admission sub-cache back into the live stack cache.
        Paged leaves take the slice's pool/free-list wholesale (the
        scatter writes only touched the admitted rows' blocks) and
        row-scatter the table; static leaves row-scatter on their batch
        axis (1 under the scan-group stacking, 0 otherwise)."""
        def sel(axis):
            def f(old, new):
                if isinstance(old, PAGED_TYPES):
                    return paged_over_groups(
                        lambda o, n_: paged_merge_rows(o, n_, idx),
                        old, new)
                if axis == 1:
                    return old.at[:, idx].set(new)
                return old.at[idx].set(new)
            return f
        leaf = lambda c: isinstance(c, PAGED_TYPES)
        return {"scan": jax.tree.map(sel(1), cache["scan"], sub["scan"],
                                     is_leaf=leaf),
                "rest": jax.tree.map(sel(0), cache["rest"], sub["rest"],
                                     is_leaf=leaf)}

    def _check_pool_health(self, state: EngineState) -> None:
        """Raise if a round silently dropped cache writes: pool
        exhaustion mid-round (sticky ``alloc_failed``, paged only) or a
        row running past its logical capacity (sticky per-row
        ``overflowed``, any attention cache) — either way the cache is
        missing K/V and those rows' generation is no longer trustworthy.
        The admission-time capacity guard makes overflow unreachable in
        ``serve_requests``; the fixed-round ``serve`` loop has no budget
        bound and relies on this check."""
        for name, cache in (("target", state.target_cache),
                            ("draft", state.draft_cache)):
            alloc = _paged_alloc_state(cache)
            if alloc is not None and bool(alloc[2]):
                raise PoolExhaustedError(
                    f"{name} KV pool exhausted during a serving round: a "
                    f"decode/verify write needed a block with none free — "
                    f"grow kv_num_blocks or admit less concurrent work")
            over = _stack_overflow_rows(cache)
            if over is not None and over.any():
                bad = np.nonzero(over)[0].tolist()
                raise CacheOverflowError(
                    f"{name} cache row(s) {bad} ran past logical capacity "
                    f"(cache_len={self.cache_len}): a chunk write past the "
                    f"last slot was dropped, so those rows' K/V is "
                    f"incomplete — grow cache_len or bound the request "
                    f"with a generation budget")

    def _release_rows(self, state: EngineState, rows: list[int]
                      ) -> EngineState:
        """Free the KV blocks of idle rows (request retired, no successor
        queued) so admissions on OTHER servers can claim them — without
        this, an undersized pool could refuse an admission while an idle
        row sits on freed-able blocks.  Paged leaves only; static caches
        need no release (masking already hides stale rows).

        Prefix-index upkeep: a released block whose refcount drops to 0
        may be reallocated by any later write, so its index entry is
        evicted HERE — the single chokepoint for non-admission frees
        (rollback can never free a registered full-prompt block: it only
        drops blocks past the write frontier)."""
        mask = np.zeros((self.n_rows,), bool)
        mask[list(rows)] = True
        mask_j = jnp.asarray(mask)
        if self.prefix_cache:
            for name, cache in (("target", state.target_cache),
                                ("draft", state.draft_cache)):
                fields = _paged_host_fields(cache)
                if fields is None:
                    continue
                _, table, ref, _ = fields
                dec: dict[int, int] = {}
                for i in rows:
                    for blk in table[i]:
                        if blk >= 0:
                            dec[int(blk)] = dec.get(int(blk), 0) + 1
                self._prefix_index[name].evict_blocks(
                    [blk for blk, d in dec.items() if ref[blk] - d <= 0])

        def fix(c):
            if isinstance(c, PAGED_TYPES):
                return reset_rows(c, mask_j)
            return c
        leaf = lambda c: isinstance(c, PAGED_TYPES)
        return state._replace(
            target_cache=jax.tree.map(fix, state.target_cache, is_leaf=leaf),
            draft_cache=jax.tree.map(fix, state.draft_cache, is_leaf=leaf))

    def _admit_rows_paged(self, state: EngineState, rows: list[int],
                          prompts: dict, draft_params,
                          target_params) -> EngineState:
        """Paged admission: free the retiring rows' blocks, allocate blocks
        for the new prompts, and prefill a batch of ONLY the admitted rows
        into the shared pools.  Raises ``PoolExhaustedError`` when the free
        list cannot hold the new prompts (clean admission error instead of
        silently dropped writes).

        With ``prefix_cache`` the per-model host index is consulted
        first: each row's longest already-cached full-block prompt prefix
        (capped at the min across the two models, so ONE suffix chunk
        serves both prefills) is ATTACHED by physical block id — refcount
        bump, no prefill compute — and only the unique suffix is fed
        through the model at its true absolute positions.  Index
        staleness is handled here for admission-triggered frees: entries
        whose blocks this admission's row resets would free are evicted
        unless the same admission re-attaches them (attach happens before
        any suffix allocation inside ``paged_write_prefill``, so a
        re-pinned block is never reallocated)."""
        rows = sorted(rows)
        k = len(rows)
        row_prompts = [np.asarray(prompts[i], np.int32) for i in rows]
        idx = jnp.asarray(rows, jnp.int32)
        feed_lens = [max(0, len(p) - 1) for p in row_prompts]
        feeds = [p[:fl] for p, fl in zip(row_prompts, feed_lens)]
        bs_cfg = self.kv_block_size

        # ---- prefix lookup + index upkeep (host side) -------------------
        shared_counts = [0] * k
        matches: dict = {}
        if self.prefix_cache:
            raw = {}
            host = {}
            for name, cache in (("target", state.target_cache),
                                ("draft", state.draft_cache)):
                fields = _paged_host_fields(cache)
                host[name] = fields
                index = self._prefix_index[name]
                # free blocks may have been reallocated by any later
                # write — their entries are stale the moment they freed
                index.evict_free(fields[2])
                raw[name] = [index.match(f, bs_cfg) for f in feeds]
            shared_counts = [min(len(raw["target"][j]), len(raw["draft"][j]))
                             for j in range(k)]
            for name in ("target", "draft"):
                _, table, ref, _ = host[name]
                # simulate this admission's own row resets: an entry whose
                # block they free dies UNLESS this admission re-attaches it
                ref_after = ref.astype(np.int64).copy()
                for i in rows:
                    for blk in table[i]:
                        if blk >= 0:
                            ref_after[blk] -= 1
                attached = {b for j in range(k)
                            for b in raw[name][j][:shared_counts[j]]}
                matches[name] = ([raw[name][j][:shared_counts[j]]
                                  for j in range(k)], ref_after, attached)
                self._prefix_index[name].evict_blocks(
                    [b for b in list(self._prefix_index[name].by_block)
                     if ref_after[b] <= 0 and b not in attached])
        shared_lens_np = np.asarray([c * bs_cfg for c in shared_counts],
                                    np.int32)
        use_shared = any(shared_counts)

        # ---- feed chunk: full prompts, or unique suffixes under sharing
        lengths = jnp.asarray([len(p) for p in row_prompts], jnp.int32)
        pend_idx = jnp.maximum(lengths - 1, 0)
        if not use_shared:
            maxlen = max(len(p) for p in row_prompts)
            toks = np.zeros((k, maxlen), np.int32)
            valid = np.zeros((k, maxlen), bool)
            for j, p in enumerate(row_prompts):
                toks[j, :len(p)] = p
                valid[j, :len(p)] = True
            toks_j = jnp.asarray(toks)
            feed_valid = jnp.asarray(valid) \
                & (jnp.arange(maxlen)[None, :] < pend_idx[:, None])
        else:
            suf_lens = [fl - sl for fl, sl in zip(feed_lens, shared_lens_np)]
            maxlen = max(1, max(suf_lens))   # all-shared rows: 1 dead token
            toks = np.zeros((k, maxlen), np.int32)
            valid = np.zeros((k, maxlen), bool)
            for j, (f, sl) in enumerate(zip(feeds, shared_lens_np)):
                toks[j, :suf_lens[j]] = f[sl:]
                valid[j, :suf_lens[j]] = True
            toks_j = jnp.asarray(toks)
            feed_valid = jnp.asarray(valid)
            shared_lens_j = jnp.asarray(shared_lens_np)
            positions_j = shared_lens_j[:, None] + jnp.arange(maxlen)[None, :]

        # Validate BOTH pools before any prefill runs: the prefill donates
        # the sub-cache, whose pool buffers alias the live state, so a
        # raise after the first prefill would leave the caller's state
        # with deleted buffers instead of a clean admission error.
        subs = {}
        for name, model, cache in (
                ("target", self.target_model, state.target_cache),
                ("draft", self.draft_model, state.draft_cache)):
            sub = self._admit_slice(model, cache, idx, k)
            alloc = _paged_alloc_state(sub)
            if alloc is not None:
                bs, free, failed = alloc
                if bool(failed):
                    raise PoolExhaustedError(
                        f"{name} KV pool: a write was dropped in an "
                        f"earlier round (sticky alloc_failed); the cache "
                        f"is not trustworthy — grow kv_num_blocks")
                if use_shared:
                    # per row: blocks_for(feed) - shared = blocks_for(suffix)
                    # (sharing is whole-block), plus one consumed free
                    # block per DISTINCT attached block that this
                    # admission's own resets left free (re-pin)
                    _, ref_after, attached = matches[name]
                    need = sum(blocks_for(sl_, bs) for sl_ in suf_lens) \
                        + sum(1 for b in attached if ref_after[b] <= 0)
                else:
                    need = sum(blocks_for(fl, bs) for fl in feed_lens)
                have = int(free.sum())
                if need > have:
                    raise PoolExhaustedError(
                        f"{name} KV pool exhausted: admission of rows "
                        f"{rows} needs {need} blocks, {have} free "
                        f"(block_size={bs}, pool={free.shape[0]})")
            subs[name] = sub

        new_caches = {}
        for name, cache, params, prefill_fn, shared_fn in (
                ("target", state.target_cache, target_params,
                 self._prefill_fn_target, self._prefill_shared_fn_target),
                ("draft", state.draft_cache, draft_params,
                 self._prefill_fn_draft, self._prefill_shared_fn_draft)):
            if use_shared:
                mrows = matches[name][0]
                ms = max(1, max(len(mr) for mr in mrows))
                sb = np.full((k, ms), -1, np.int32)
                for j, mr in enumerate(mrows):
                    sb[j, :len(mr)] = mr
                out = shared_fn(params, toks_j, subs[name], feed_valid,
                                positions_j, jnp.asarray(sb), shared_lens_j)
            else:
                out = prefill_fn(params, toks_j, subs[name], feed_valid)
            alloc = _paged_alloc_state(out.cache)
            # defensive only: the pre-checks above make this unreachable
            # (prefill allocates exactly the pre-counted prompt blocks)
            assert alloc is None or not bool(alloc[2]), \
                f"{name} pool allocation failed despite free-count check"
            if self.prefix_cache:
                # register every FULL feed block of the fresh rows so the
                # next admission can share them (first writer wins)
                fields = _paged_host_fields(out.cache)
                for j, f in enumerate(feeds):
                    nfull = len(f) // bs_cfg
                    if nfull:
                        self._prefix_index[name].register(
                            f, fields[1][j, :nfull], bs_cfg)
            new_caches[name] = self._merge_admit(cache, out.cache, idx)

        pending = jnp.asarray([int(p[-1]) if len(p) else 0
                               for p in row_prompts], jnp.int32)
        return state._replace(
            target_cache=new_caches["target"],
            draft_cache=new_caches["draft"],
            pending=state.pending.at[idx].set(pending),
            length=state.length.at[idx].set(pend_idx))

    # ------------------------------------------------------------------
    def _draft(self, params, cache, pending: Array, length: Array,
               key: Array, active: Array, vmask: Optional[Array],
               steps: Optional[int] = None,
               budgets: Optional[Array] = None):
        """Step (1): each server decodes ``steps`` (default s_max) tokens
        (rows with S_i < s_max mask the tail).  Returns draft tokens,
        their q logits, updated cache.

        Idle rows (active[b] = False) are masked out of the cache writes:
        their draft tokens are discarded anyway, and under ``paged_kv`` an
        unmasked idle-row write would allocate pool blocks a live row may
        need.

        vmask: the pad-vocab mask from ``_vocab_mask``, built ONCE per
        round and closed over here — not rebuilt in every scan step.

        budgets: optional i32[N*R] per-row write budget — the speculative
        draft-ahead masks cache writes past its planned S so the
        one-round-late discard has less tail to free.  None (the real
        draft) keeps the historical behaviour: every active row writes
        all ``steps`` tokens and rollback cleans past the accepted
        prefix."""
        s_cap = self.s_max if steps is None else steps
        # draft_temps are per SERVER (hardware heterogeneity); each of a
        # server's lanes samples at its server's temperature
        temps = jnp.repeat(jnp.asarray(
            self.draft_temps or (1.0,) * self.n_servers, jnp.float32),
            self.lanes)

        def dec(carry, t):
            cache, tok, pos, key = carry
            key, k_s = jax.random.split(key)
            valid = active if budgets is None else active & (t < budgets)
            out = self.draft_model.forward(
                params, tok[:, None], mode="decode", cache=cache,
                positions=pos[:, None], chunk_valid=valid[:, None])
            logits = out.logits[:, 0, :]  # [N, Vp]
            if vmask is not None:
                logits = logits + vmask
            # q := the ACTUAL sampling distribution (incl. temperature) —
            # rejection sampling is only lossless w.r.t. the true q.
            logits = logits / temps[:, None]
            if self.greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                nxt = jax.random.categorical(k_s, logits, axis=-1)
            return (out.cache, nxt.astype(jnp.int32), pos + 1, key), \
                (nxt.astype(jnp.int32), logits)

        (cache, _, _, _), (toks, qlogits) = jax.lax.scan(
            dec, (cache, pending, length, key), jnp.arange(s_cap))
        # scan stacks time-first: [S, N] -> [N, S]
        return toks.swapaxes(0, 1), qlogits.swapaxes(0, 1), cache

    @staticmethod
    def _vocab_mask(cfg: ModelConfig) -> Optional[Array]:
        """Additive mask hiding the padded vocab tail (None when the vocab
        is unpadded).  Hoisted out of the per-token draft scan body: the
        mask is built once per round and closed over."""
        if cfg.padded_vocab <= cfg.vocab_size:
            return None
        pad = cfg.padded_vocab - cfg.vocab_size
        return jnp.concatenate([jnp.zeros((cfg.vocab_size,)),
                                jnp.full((pad,), -1e30)])

    # ------------------------------------------------------------------
    def _verify_chunk(self, params, tcache, pending: Array, length: Array,
                      draft_toks: Array, S: Array, active: Array,
                      vmask: Optional[Array]):
        """Step (4a): target scores [pending, d_1..d_{S-1}, d_S] in one
        decode-chunk; output j is the distribution of chunk position j+1.
        Inactive (idle-lane) rows are masked out of the chunk entirely —
        their caches see no writes and they commit nothing."""
        n, s_cap = self.n_rows, self.s_max
        chunk = jnp.concatenate([pending[:, None], draft_toks], axis=1)
        in_draft = jnp.arange(s_cap)[None, :] < S[:, None]
        chunk_valid = active[:, None] & jnp.concatenate(
            [jnp.ones((n, 1), bool), in_draft], axis=1)
        positions = length[:, None] + jnp.cumsum(
            chunk_valid.astype(jnp.int32), axis=1) - 1
        out = self.target_model.forward(
            params, chunk, mode="decode", cache=tcache,
            positions=positions, chunk_valid=chunk_valid)
        p_logits = out.logits if vmask is None else out.logits + vmask
        return p_logits, out.cache, in_draft

    # ------------------------------------------------------------------
    def _draft_phase(self, draft_params, dcache, pending: Array,
                     length: Array, est: EstimatorState, key: Array,
                     caps: Array) -> DraftOut:
        """``draft_dispatch``: round-graph phase 1 — split the round key,
        plan the per-lane budgets ON DEVICE from the estimator state
        (step 0: GOODSPEED-SCHED at server granularity, water-filled over
        lanes by ``core.scheduler.plan_budgets``), and run the draft
        decode scan.

        caps: i32[N*R] per-LANE remaining-token budget (server-major).
        cap == 0 marks an IDLE lane: it gets S = 0 from the splitter, is
        masked out of the verify chunk and commits nothing.  A server
        whose lanes are all idle gets S_i = 0 from the scheduler (inside
        the solver, so the budget flows to live servers) and its
        estimator state holds."""
        key, k_draft, k_verify, k_sched, k_jit = jax.random.split(key, 5)
        n, lanes = self.n_servers, self.lanes
        active = caps > 0
        lane_cap = jnp.minimum(caps, self.s_max)          # i32[N*R]
        w = self.utility.grad(est.goodput)
        S = plan_budgets(self._sched, est.alpha_hat, w, self.C,
                         lane_cap.reshape(n, lanes), self.s_max,
                         key=k_sched)                     # i32[N*R]
        S = jnp.where(active, S, 0)
        # pad-vocab mask built once per round (closed over by the draft
        # scan body instead of rebuilt per token)
        vmask_d = self._vocab_mask(self.draft_model.cfg)
        draft_toks, q_logits, cache = self._draft(
            draft_params, dcache, pending, length, k_draft, active, vmask_d)
        return DraftOut(toks=draft_toks, qlogits=q_logits, S=S,
                        active=active, cache=cache, k_verify=k_verify,
                        k_jit=k_jit, key=key)

    def _verify_phase(self, target_params, tcache, pending: Array,
                      length: Array, toks: Array, qlogits: Array, S: Array,
                      active: Array, k_verify: Array) -> VerifyOut:
        """``verify_dispatch``: round-graph phase 2 — score the ragged
        [pending, d_1..d_S] chunk in one target decode-chunk forward and
        run lossless rejection sampling (core.speculative.verify)."""
        vmask_t = self._vocab_mask(self.target_model.cfg)
        p_logits, cache, _ = self._verify_chunk(
            target_params, tcache, pending, length, toks, S, active, vmask_t)
        res = verify(k_verify, toks, qlogits, p_logits, S,
                     backend=self.attn_backend, greedy=self.greedy)
        m = jnp.where(active, res.accepted, 0)
        num_emitted = jnp.where(active, res.num_emitted, 0)
        return VerifyOut(
            cache=cache, accepted=m, num_emitted=num_emitted,
            extra_token=res.extra_token,
            emitted=jnp.where(active[:, None], res.emitted, -1),
            ratio_sum=jnp.where(active, res.accept_ratio_sum, 0.0))

    def _ahead_phase(self, draft_params, dcache, toks: Array, S: Array,
                     active: Array, length: Array, est: EstimatorState,
                     caps: Array, key: Array):
        """Speculative draft-ahead for round t+1 (overlap mode only):
        continue drafting from each lane's round-t draft tail (root
        token d_S at position length+S) over the post-draft cache buffer
        while round t's verify chunk is conceptually in flight —
        speculative-on-speculative.  Budgets come from ROUND t-1's
        observations: ``est`` is the state BEFORE round t's update (the
        estimator update lands one round late relative to this dispatch)
        and ``caps`` are round t's remaining caps.  The tail is ALWAYS
        discarded exactly at reconcile (the true round t+1 re-drafts from
        the committed state — a rejected root invalidates the
        continuation, and even on full acceptance the bonus token is only
        sampled inside verify), so the ahead can never change what is
        accepted; its value is the modeled distributed-timing win
        (LatencyModel.overlapped_round_time) and keeping the device busy
        while the host reconciles.  Returns (polluted cache, ahead
        budgets, pre-ahead sticky-flag snapshot)."""
        # mirror the NEXT round's key split so the ahead consumes the
        # same draft/sched streams the real round t+1 will draw
        _, k_draft, _, k_sched, _ = jax.random.split(key, 5)
        n, lanes = self.n_servers, self.lanes
        live = active & (S > 0)
        lane_cap = jnp.minimum(caps, self.s_max)
        w = self.utility.grad(est.goodput)
        S_ahead = plan_budgets(self._sched, est.alpha_hat, w, self.C,
                               lane_cap.reshape(n, lanes), self.s_max,
                               key=k_sched)
        S_ahead = jnp.where(live, jnp.minimum(S_ahead, self.s_bucket), 0)
        # draft-tail snapshot: the sticky flags the deferred discard
        # restores (ahead-writes may exhaust a pool, or run a row past
        # capacity, in ways the real round won't)
        flag = _stack_sticky_flags(dcache)
        root = jnp.take_along_axis(
            toks, jnp.maximum(S - 1, 0)[:, None], axis=1)[:, 0]
        vmask_d = self._vocab_mask(self.draft_model.cfg)
        _, _, cache = self._draft(
            draft_params, dcache, jnp.where(live, root, 0), length + S,
            k_draft, live, vmask_d, steps=self.s_bucket, budgets=S_ahead)
        return cache, S_ahead, flag

    def _reconcile_phase(self, draft_params, target_params, dcache, tcache,
                         dcache_ckpt, tcache_ckpt, est: EstimatorState,
                         pending: Array, length: Array, prev_S: Array,
                         toks: Array, S: Array, active: Array, v: VerifyOut,
                         k_jit: Array, key: Array, deferred: bool,
                         saved_flag: Optional[StickyFlags] = None,
                         faults: Optional[RoundFaults] = None):
        """``reconcile``: round-graph phase 3 — apply acceptance/rollback
        to both caches, update the estimators (Eqs. 3-4), price the round
        (LatencyModel) and assemble the next EngineState.

        deferred=False (synchronous round): plain rollback to the
        committed boundary; ``*_ckpt`` are the pre-chunk checkpoints the
        recompute strategy needs for non-rollbackable stacks.

        deferred=True (overlap): the draft cache arrives POLLUTED by the
        round-(t+1) draft-ahead, whose writes start at counter
        length + s_max (the real draft writes all s_max steps for active
        rows; rollback has always cleaned past the accepted prefix).
        ``keep = length + min(m+1, s_max)`` therefore restores the
        bit-exact synchronous post-round state: for m <= S < s_max it
        equals the sync boundary, and at full acceptance (m = S = s_max)
        it additionally drops the ahead-root's write at counter
        length+s_max — a slot the synchronous round never wrote.  Paged
        free-lists restore exactly too (the allocator is a deterministic
        first-free mask), with the sticky alloc_failed flag reset to the
        pre-ahead snapshot (``kv_cache.discard_tail``).

        faults (``serving.faults.RoundFaults``) carries this round's
        per-server straggler/uplink multipliers, payload drops and the
        verify DEADLINE.  A live server whose simulated chunk arrival
        exceeds the deadline (or whose payload dropped) MISSES the round:
        its speculative tokens are discarded — zero accepted, no bonus,
        ratio sums zeroed (the estimator holds, exactly as for an
        unobserved server) and both caches roll back to the committed
        boundary, while every other server's round is untouched.  The
        nominal faults (all multipliers 1.0, deadline inf) are a bitwise
        no-op on every output, so fault-free traces stay byte-identical
        to the historical round."""
        cfg_t = self.target_model.cfg
        n, lanes = self.n_servers, self.lanes
        m, num_emitted = v.accepted, v.num_emitted

        # ---- verify deadline (fault model) -------------------------------
        # jitter is drawn here (same k_jit stream as always) because the
        # per-server arrival times both decide the deadline misses and
        # price the round below
        jitter = jax.random.uniform(k_jit, (n * lanes,),
                                    minval=-1.0, maxval=1.0)
        if faults is None:
            faults = RoundFaults.nominal(n)
        slow = jnp.asarray(faults.slow, jnp.float32)
        uplink = jnp.asarray(faults.uplink, jnp.float32)
        dropped = jnp.asarray(faults.dropped, bool)
        deadline = jnp.asarray(faults.deadline, jnp.float32)
        arrival, live = self.latency.server_arrival_times(
            S, cfg_t.vocab_size, jitter, lanes=lanes,
            slow=slow, uplink=uplink)
        missed = live & (dropped | (arrival > deadline))
        ok_row = jnp.repeat(~missed, lanes)           # bool[N*R]
        m = jnp.where(ok_row, m, 0)
        num_emitted = jnp.where(ok_row, num_emitted, 0)
        emitted = jnp.where(ok_row[:, None], v.emitted, -1)
        ratio_sum = jnp.where(ok_row, v.ratio_sum, 0.0)
        S_obs = jnp.where(ok_row, S, 0)               # what verify saw
        realized = num_emitted.astype(jnp.float32)

        # ---- commit / rollback -------------------------------------------
        new_length = length + num_emitted             # m+1 tokens if active
        keep_pos = new_length                         # cache keeps < keep
        m_eff = jnp.where(active & ok_row, m, -1)     # -1: recompute holds
        if _is_rollbackable(cfg_t):
            tcache = _cache_rollback(tcache, keep_pos)
        else:
            tcache = self._recompute_cache(
                self.target_model, target_params, tcache_ckpt,
                pending, toks, m_eff, length)
        if deferred:
            draft_keep = length + jnp.minimum(num_emitted, self.s_max)
            dcache = _cache_discard_tail(dcache, draft_keep, saved_flag)
        elif _is_rollbackable(self.draft_model.cfg):
            dcache = _cache_rollback(dcache, keep_pos)
        else:
            dcache = self._recompute_cache(
                self.draft_model, draft_params, dcache_ckpt,
                pending, toks, m_eff, length)

        # ---- estimator update (step 5): per-SERVER aggregation over the
        # server's lanes (Eq. 3 divides the summed accept ratios by the
        # summed verified positions; Eq. 4's x_i is the server's total
        # emitted tokens).  Unobserved servers (no lane drafted: S_i = 0)
        # hold BOTH estimates inside the estimator — an idle server must
        # not have its fairness weight dragged by rounds it never saw.
        est = self.estimator.update(
            est,
            ratio_sum.reshape(n, lanes).sum(axis=1),
            S_obs.reshape(n, lanes).sum(axis=1),
            realized.reshape(n, lanes).sum(axis=1))

        # latency sees per-lane rows with the lane grouping: a server's
        # lanes draft in one batched decode (receive = max over its
        # lanes) but share its uplink (payloads sum per server), while
        # the verify chunk and downlink pay for every lane's tokens.
        # Under a finite deadline the batch assembles at min(slowest live
        # arrival, deadline) — the verify server stops waiting — and the
        # dropped chunks cost no verify/downlink time (S_obs/num_emitted
        # are already masked).  With nominal faults this is bit-identical
        # to LatencyModel.round_time / overlapped_round_time.
        rt = jnp.minimum(jnp.max(jnp.where(live, arrival, 0.0)), deadline)
        vt = self.latency.verify_time(S_obs)
        st = self.latency.send_time(num_emitted)
        total = rt + vt + st
        if deferred:
            # overlapped pipeline: round t's drafts were produced while
            # round t-1's chunk (prev_S) was still being verified
            total_ov = jnp.maximum(rt, self.latency.verify_time(prev_S)) + st
        else:
            total_ov = total

        pending = jnp.where(active & ok_row, v.extra_token, pending)
        new_state = EngineState(
            target_cache=tcache, draft_cache=dcache,
            pending=pending, length=new_length, est=est, S=S_obs, key=key)
        stats = (S, m, realized, est.alpha_hat, est.goodput,
                 self.utility.value(est.goodput),
                 jnp.stack([total, rt, vt, st]), emitted, total_ov,
                 missed, arrival)
        return new_state, stats

    def _reconcile_overlap(self, draft_params, target_params, dcache,
                           tcache, est, pending, length, prev_S, toks, S,
                           active, v, k_jit, key, saved_flag,
                           faults: Optional[RoundFaults] = None):
        """jit entry for the overlap reconcile (donated polluted caches;
        rollbackable stacks asserted at construction, so no checkpoints)."""
        return self._reconcile_phase(
            draft_params, target_params, dcache, tcache, None, None, est,
            pending, length, prev_S, toks, S, active, v, k_jit, key,
            deferred=True, saved_flag=saved_flag, faults=faults)

    def _round_core(self, state: EngineState, draft_params, target_params,
                    caps: Array, faults: Optional[RoundFaults] = None):
        """One full Algorithm-1 round (jit'd, state donated): the round
        graph composed synchronously — plan/draft -> verify -> reconcile
        inside one compiled graph, byte-identical to the historical
        monolithic round."""
        d = self._draft_phase(draft_params, state.draft_cache,
                              state.pending, state.length, state.est,
                              state.key, caps)
        v = self._verify_phase(target_params, state.target_cache,
                               state.pending, state.length, d.toks,
                               d.qlogits, d.S, d.active, d.k_verify)
        return self._reconcile_phase(
            draft_params, target_params, d.cache, v.cache,
            state.draft_cache, state.target_cache, state.est,
            state.pending, state.length, state.S, d.toks, d.S, d.active,
            v, d.k_jit, d.key, deferred=False, faults=faults)

    # ------------------------------------------------------------------
    def plan_round(self, caps: Optional[np.ndarray] = None,
                   admitted: tuple = ()) -> RoundPlan:
        """Build the host-side coordinator of the next round.  caps
        (i32[N*R], per lane) defaults to "every lane live at full s_max"
        (the fixed-round simulator behaviour)."""
        if caps is None:
            caps = np.full((self.n_rows,), self.s_max, np.int32)
        return RoundPlan(caps=np.asarray(caps, np.int32),
                         s_bucket=self.s_bucket, overlap=self.overlap,
                         admitted=tuple(admitted))

    def dispatch_round(self, state: EngineState, draft_params,
                       target_params,
                       caps: Optional[np.ndarray] = None,
                       plan: Optional[RoundPlan] = None,
                       faults: Optional[RoundFaults] = None):
        """Device dispatch of one round: enqueue the phase jits and
        return ``(new_state, raw_stats, ahead_S)`` with every leaf still
        an on-device buffer — NO host sync.  ``ahead_S`` is None in sync
        mode.  All host inputs (caps, fault arrays) are converted
        EXPLICITLY here (``jnp.asarray``), so a steady-state dispatch is
        clean under ``jax.transfer_guard("disallow")`` — the transfer
        fence (serving.guards, tests/test_trace_guard.py) wraps exactly
        this call.  ``run_round`` adds the host materialization of
        ``RoundStats``, the round's one sanctioned sync point."""
        if plan is None:
            plan = self.plan_round(caps)
        # dtype-normalize on HOST first: jnp.asarray of an array whose
        # dtype already matches is an EXPLICIT transfer (clean under
        # transfer_guard("disallow")), while a converting jnp.asarray —
        # or a bare numpy/python scalar like the fault deadline — moves
        # implicitly and trips the fence
        caps_j = jnp.asarray(np.asarray(plan.caps, np.int32))
        if faults is not None:
            faults = RoundFaults(
                slow=jnp.asarray(np.asarray(faults.slow, np.float32)),
                uplink=jnp.asarray(np.asarray(faults.uplink, np.float32)),
                dropped=jnp.asarray(np.asarray(faults.dropped, bool)),
                deadline=jnp.asarray(np.asarray(faults.deadline,
                                                np.float32)))
        if not plan.overlap:
            new_state, raw = self._round_fn(
                state, draft_params, target_params, caps_j, faults)
            return new_state, raw, None
        d = self._draft_fn(draft_params, state.draft_cache,
                           state.pending, state.length, state.est,
                           state.key, caps_j)
        v = self._verify_fn(target_params, state.target_cache,
                            state.pending, state.length, d.toks,
                            d.qlogits, d.S, d.active, d.k_verify)
        ahead_cache, ahead_S_j, flag = self._ahead_fn(
            draft_params, d.cache, d.toks, d.S, d.active,
            state.length, state.est, caps_j, d.key)
        new_state, raw = self._reconcile_fn(
            draft_params, target_params, ahead_cache, v.cache,
            state.est, state.pending, state.length, state.S, d.toks,
            d.S, d.active, v, d.k_jit, d.key, flag, faults)
        return new_state, raw, ahead_S_j

    def run_round(self, state: EngineState, draft_params, target_params,
                  caps: Optional[np.ndarray] = None,
                  plan: Optional[RoundPlan] = None,
                  faults: Optional[RoundFaults] = None
                  ) -> tuple[EngineState, RoundStats]:
        """One round of the round graph.  NOTE: ``state`` is donated to
        the compiled phases — use the returned state, not the argument.

        overlap=False: one composed dispatch (plan -> draft -> verify ->
        reconcile in a single jit).  overlap=True: four phase dispatches
        enqueue back-to-back with NO host sync in between — verify_t and
        the round-(t+1) draft-ahead are in flight together, and the
        deferred reconcile (one round late from the ahead's perspective)
        discards the ahead tail exactly; the host only blocks when it
        reads the round's stats.  The device half is ``dispatch_round``;
        this wrapper adds the ``RoundStats`` host materialization.

        faults: this round's ``RoundFaults`` (``FaultPlan.round_faults``)
        — per-server straggler/uplink multipliers, payload drops and the
        verify deadline.  The arrays enter the reconcile as TRACED leaves
        (one extra compiled variant per phase, shared by every faulted
        round); None keeps the fault-free graph byte-identical to the
        historical round."""
        new_state, raw, ahead_S_j = self.dispatch_round(
            state, draft_params, target_params, caps=caps, plan=plan,
            faults=faults)
        ahead_S = np.zeros((self.n_rows,), np.int32) if ahead_S_j is None \
            else np.asarray(ahead_S_j)
        (S, m, realized, alpha_hat, goodput, util, wall, emitted, ov,
         missed, arrival) = raw
        stats = RoundStats(
            S=np.asarray(S), accepted=np.asarray(m),
            realized=np.asarray(realized), alpha_hat=np.asarray(alpha_hat),
            goodput_est=np.asarray(goodput), utility=float(util),
            wall=np.asarray(wall), emitted=np.asarray(emitted),
            wall_overlap=float(ov), ahead_S=ahead_S,
            missed=np.asarray(missed), arrival=np.asarray(arrival))
        return new_state, stats

    def round_trace_counts(self) -> dict:
        """Compiled-variant count per round-phase jit — the retrace
        telemetry ``benchmarks/serve_requests.py`` asserts against (a
        serving run must never retrace a phase more than once per
        engine bucket shape)."""
        fns = {"round": self._round_fn} if not self.overlap else {
            "draft": self._draft_fn, "verify": self._verify_fn,
            "ahead": self._ahead_fn, "reconcile": self._reconcile_fn}
        return {name: f._cache_size() for name, f in fns.items()}

    # ------------------------------------------------------------------
    def _recompute_cache(self, model: Model, params, checkpoint_cache,
                         pending: Array, draft_toks: Array, m: Array,
                         length: Array):
        """Recompute strategy: advance the PRE-CHUNK cache by the accepted
        prefix [pending, d_1..d_m] only (masked chunk; m = -1 keeps the
        row's checkpoint untouched)."""
        n, s_cap = draft_toks.shape
        chunk = jnp.concatenate([pending[:, None], draft_toks], axis=1)
        valid = jnp.arange(s_cap + 1)[None, :] <= m[:, None]
        positions = length[:, None] + jnp.arange(s_cap + 1)[None, :]
        out = model.forward(params, chunk, mode="decode",
                            cache=checkpoint_cache, positions=positions,
                            chunk_valid=valid)
        return out.cache

    # ------------------------------------------------------------------
    def _refresh_kv_blocks(self, state: EngineState,
                           mgr: RequestManager) -> None:
        """Recompute every seated request's ``kv_blocks`` from the LIVE
        block table (bugfix: the old admission-time snapshot never moved
        as verify chunks allocated blocks and rollback/retirement freed
        them, so ``stats()['kv_blocks_active']`` drifted from the true
        free list).  Under prefix sharing a block referenced r times
        contributes 1/r to each holder — attributed shares sum exactly to
        the allocated block count, so at the call point (right after
        admissions, when every allocated block belongs to a seated
        request) ``kv_blocks_active == P - free_count`` holds."""
        fields = _paged_host_fields(state.target_cache)
        if fields is None:
            return
        _, table, ref, _ = fields
        for i in range(self.n_rows):
            req = mgr.active[i]
            if req is None:
                continue
            req.kv_blocks = float(sum(1.0 / ref[b] for b in table[i]
                                      if b >= 0 and ref[b] > 0))

    # ------------------------------------------------------------------
    def _placement_view(self, state: EngineState, mgr: RequestManager
                        ) -> PlacementView:
        """Live per-server view the placement policy decides against:
        queue loads and caps from the manager, alpha_hat from the round
        estimator, and (paged only) the min free-block count across the
        two pools — read from the small allocator fields, never the
        pool buffers."""
        free_blocks = total_blocks = None
        if self.paged_kv:
            frees, totals = [], []
            for cache in (state.target_cache, state.draft_cache):
                alloc = _paged_alloc_state(cache)
                if alloc is not None:
                    free = np.asarray(alloc[1])
                    frees.append(int(free.sum()))
                    totals.append(int(free.shape[0]))
            if frees:
                free_blocks, total_blocks = min(frees), min(totals)
                # reserve the ACTIVE lanes' same-round growth: each live
                # lane's verify chunk (<= s_max+1 tokens) may claim up to
                # blocks_for(s_max+1) fresh blocks this round, and an
                # admission that takes them would trip the sticky
                # alloc_failed mid-round — the crash deferral prevents
                n_active = int((mgr.remaining_caps() > 0).sum())
                free_blocks = max(0, free_blocks - n_active * blocks_for(
                    self.s_max + 1, self.kv_block_size))
        return PlacementView(
            queue_load=mgr.queue_load(),
            active_remaining=mgr.server_remaining(),
            alpha_hat=np.asarray(state.est.alpha_hat, np.float32),
            alpha_init=self.estimator.alpha_init,
            s_max=self.s_max,
            free_blocks=free_blocks,
            total_blocks=total_blocks,
            block_size=self.kv_block_size,
            # None when every server is up, so the fault-free argmin tie
            # behaviour is untouched byte-for-byte
            available=(None if mgr.available.all()
                       else mgr.available.copy()))

    def _rewarm_estimator(self, est: EstimatorState,
                          servers: list[int]) -> EstimatorState:
        """Reset a rejoining server's quarantined estimates to the cold
        init values: while DOWN it drafted nothing (caps masked to 0), so
        the hold-on-unobserved guard froze its alpha_hat/X^beta at
        whatever the pre-crash rounds left — stale state a changed
        post-rejoin reality (re-warmed caches, different load) should not
        inherit.  Cold-start re-warm also makes GoodputPlacement treat
        the returnee as unproven rather than as its old self."""
        idx = jnp.asarray(sorted(servers), jnp.int32)
        return est._replace(
            alpha_hat=est.alpha_hat.at[idx].set(self.estimator.alpha_init),
            goodput=est.goodput.at[idx].set(self.estimator.goodput_init))

    # ------------------------------------------------------------------
    def serve(self, key: Array, prompts: list[np.ndarray], draft_params,
              target_params, rounds: int) -> list[RoundStats]:
        """Fixed-round simulator: every lane decodes forever (no request
        lifecycle; one prompt per lane row, n_servers * lanes entries).
        The paper's Fig. 2-4 experiments run through here."""
        state = self.init(key, prompts, draft_params, target_params)
        history = []
        for _ in range(rounds):
            state, stats = self.run_round(state, draft_params, target_params)
            # unlike serve_requests, nothing bounds a row's growth here —
            # a row that outruns cache_len must fail loudly, not decode on
            # silently truncated K/V
            self._check_pool_health(state)
            history.append(stats)
        return history

    # ------------------------------------------------------------------
    def serve_requests(self, key: Array, workload, draft_params,
                       target_params, rounds: int,
                       manager: Optional[RequestManager] = None,
                       faults: Optional[FaultPlan] = None,
                       strict_compile=False) -> dict:
        """Multi-user serving: drain a request workload with continuous
        batching (the production loop; see module docstring).

        workload: an iterable of ``Request`` (all arrive at round 0,
        round-robin server hints) or of ``(arrival_round, server,
        Request)`` triples for timed arrivals; ``server`` is binding under
        ``placement="static"`` and an advisory hint otherwise (None is
        allowed for non-static policies).  Placement is decided at
        admission time against the live estimator state and free KV
        blocks (``_placement_view``).  Runs at most ``rounds`` rounds,
        stopping early once every request has completed.

        faults: a ``serving.faults.FaultPlan`` — the adversary script plus
        mitigation config.  Each round the plan's dense ``RoundFaults``
        enter the jit'd round (stragglers/uplink degradation feed the
        verify DEADLINE check; late or dropped chunks are discarded
        exactly) and a host-side ``HealthTracker`` folds the resulting
        per-server misses: healthy -> suspect (budget haircut) ->
        down (k_down consecutive misses, or a scripted crash).  A DOWN
        server's caps mask to zero, placement stops routing to it, and —
        with ``plan.migrate`` — its in-flight requests return to the
        global queue with committed tokens preserved (exact migration:
        re-admission re-prefills from the committed prefix; under
        ``greedy=True`` the emitted sequences are byte-identical to an
        uninterrupted run).  ``migrate=False`` models the unmitigated
        system: the crashed server's seated requests are flagged lost.
        On a scripted rejoin the server's quarantined estimator state is
        re-warmed to the cold init (``_rewarm_estimator``).

        strict_compile: enforce the retrace budget at runtime
        (serving.guards.TraceGuard).  ``True`` allows each round-phase
        jit ``1`` new compiled variant over the whole drain (``2`` when
        a fault plan is active — the traced-faults graph is one extra
        shared variant); an int sets the budget explicitly, and ``0``
        is a valid budget — a PRE-WARMED engine re-serving the same
        bucket shapes must not compile at all (``False``, the default,
        disables the guard).  The guard checks after EVERY executed
        round and raises ``RetraceError`` naming the phase and round,
        instead of the retrace surfacing rounds later as a benchmark
        regression.

        Returns ``{"requests": [...], "rounds": [RoundStats...],
        "summary": {...}}`` with per-request latency (arrival -> finish,
        in rounds), queue delay, and token counts.  ``rounds_run`` counts
        EXECUTED rounds; all-idle rounds spent waiting for future arrivals
        only tick the clock.  Pass the returned manager back in (with more
        rounds) to resume an interrupted drain — mid-flight requests are
        re-prefilled from prompt + generated-so-far.
        """
        n, rows = self.n_servers, self.n_rows
        mgr = manager if manager is not None \
            else RequestManager(n, placement=self.placement,
                                lanes=self.lanes)
        assert mgr.rows == rows, \
            (f"manager has {mgr.n} servers x {mgr.lanes} lanes but the "
             f"engine runs {self.n_servers} x {self.lanes}")
        plan = faults
        tracker = None if plan is None else HealthTracker(
            n, k_down=plan.k_down, suspect_haircut=plan.suspect_haircut)
        sched = []
        for j, item in enumerate(workload):
            if isinstance(item, Request):
                sched.append((0, j % n, item))
            else:
                arr, srv, req = item
                sched.append((int(arr), None if srv is None
                              else int(srv), req))
        sched.sort(key=lambda x: x[0])

        def ctx(req: Request) -> np.ndarray:
            """Committed context of a request: prompt + tokens generated in
            a previous (interrupted) serve_requests call."""
            return np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated, np.int32)])

        guard = None
        if strict_compile is not False:
            budget = (1 if faults is None else 2) \
                if strict_compile is True else int(strict_compile)
            guard = TraceGuard(self, budget=budget).__enter__()
        # All slots start idle and masked; first admission re-prefills.
        state = self.cold_start(key)
        # requests already active in a caller-supplied manager need their
        # rows rebuilt too — this engine state starts cold
        carried = [i for i in range(rows) if mgr.active[i] is not None
                   and not mgr.active[i].done]
        prev_done = len(mgr.completed)     # completions from earlier calls
        history: list[RoundStats] = []
        next_arrival = 0
        released: set[int] = set()         # idle rows whose blocks are freed
        for r in range(rounds):
            if tracker is not None:
                # fault-plan events land BEFORE this round's admissions so
                # an eviction's requests can re-place immediately and a
                # rejoined server can seat work this same round
                for srv in plan.crashes_at(r):
                    tracker.crash(srv)
                for srv in plan.rejoins_at(r):
                    if tracker.rejoin(srv):
                        state = state._replace(
                            est=self._rewarm_estimator(state.est, [srv]))
                for srv in tracker.take_newly_down():
                    if plan.migrate:
                        mgr.evict_server(srv)
                    else:
                        mgr.mark_lost(srv)
                mgr.set_available(tracker.available())
                # a caller-supplied manager's carried rows may have been
                # evicted before their cold-state rebuild
                carried = [i for i in carried if mgr.active[i] is not None]
            while next_arrival < len(sched) and sched[next_arrival][0] <= r:
                _, srv, req = sched[next_arrival]
                mgr.submit(srv, req)
                next_arrival += 1
            mgr.retire_done()
            if self.paged_kv:
                # a retired row holds blocks another server's admission may
                # need — release BEFORE the placement view reads the free
                # list, so admission and the pool pre-check see them
                newly_idle = [i for i in range(rows)
                              if mgr.active[i] is None and i not in released]
                if newly_idle:
                    state = self._release_rows(state, newly_idle)
                    released.update(newly_idle)
            # build the (device-syncing, under paged_kv) placement view
            # only when an admission decision is actually pending: a free
            # slot AND waiting work (global arrivals or bound queues)
            if (mgr.arrivals or any(mgr.queues)) \
                    and any(a is None for a in mgr.active):
                view = self._placement_view(state, mgr)
                if carried and view.free_blocks is not None:
                    # resumed drain: carried rows' contexts are not yet in
                    # the cold pools but this round's _admit_rows will
                    # re-prefill them — reserve their blocks so the gate
                    # cannot admit an arrival those rows need
                    view.free_blocks = max(0, view.free_blocks - sum(
                        blocks_for(max(0, len(ctx(mgr.active[i])) - 1),
                                   self.kv_block_size) for i in carried))
                fresh = sorted(set(mgr.admit(view)) | set(carried))
            else:
                fresh = sorted(carried)
            carried = []
            released.difference_update(fresh)
            if fresh:
                state = self._admit_rows(
                    state, fresh, {i: ctx(mgr.active[i]) for i in fresh},
                    draft_params, target_params,
                    budgets={i: mgr.active[i].remaining for i in fresh})
            if self.paged_kv:
                # per-request block accounting from the live table — at
                # this point (post-release, post-admission) every
                # allocated block belongs to a seated request, so the
                # attributed shares sum to exactly P - free_count
                self._refresh_kv_blocks(state, mgr)
            if mgr.idle() and next_arrival >= len(sched):
                break                      # workload drained
            caps = mgr.remaining_caps()
            if tracker is not None:
                # health masking on top of the request caps: DOWN -> 0
                # (budget flows to live servers inside the solver),
                # SUSPECT -> haircut
                caps = tracker.apply_caps(caps, self.lanes, self.s_max)
            if not caps.any():
                mgr.tick()                 # all idle: await arrivals without
                continue                   # burning a full model round
            rf = plan.round_faults(r, n) if plan is not None else None
            state, stats = self.run_round(state, draft_params, target_params,
                                          caps=caps, faults=rf)
            if guard is not None:
                guard.check(f"round {r}")
            if self.paged_kv:
                self._check_pool_health(state)
            mgr.record_emitted(stats.emitted)
            if tracker is not None:
                drafted = stats.S.reshape(n, self.lanes).sum(axis=1) > 0
                tracker.observe_round(drafted, stats.missed)
            history.append(stats)
        mgr.retire_done()                  # last-round completions (retire
                                           # ONLY: admitting here would seat
                                           # requests no round will serve)

        # per-request records and throughput cover THIS call's completions;
        # mgr.stats() keys keep the manager-lifetime view (resume-safe).
        requests = [{
            "request_id": req.request_id,
            "server": (req.placed_server if req.placed_server is not None
                       else req.server_hint),
            "lane": req.placed_lane,
            "arrival_round": req.arrival_round,
            "admit_round": req.admit_round,
            "finish_round": req.finish_round,
            "latency_rounds": req.finish_round - req.arrival_round,
            "queue_delay_rounds": (req.admit_round - req.arrival_round
                                   if req.admit_round is not None else None),
            "tokens": len(req.generated),
            "generated": list(req.generated),
            "kv_blocks": req.kv_blocks,
            "migrations": req.migrations,
        } for req in mgr.completed[prev_done:]]
        rounds_run = len(history)
        toks_done = sum(r["tokens"] for r in requests)
        summary = dict(mgr.stats(),
                       rounds_run=rounds_run,
                       completed_this_call=len(requests),
                       # workload items whose arrival_round fell past the
                       # rounds budget — never submitted to the manager
                       unsubmitted=len(sched) - next_arrival,
                       tokens_per_round=toks_done / max(1, rounds_run),
                       requests_per_round=len(requests) / max(1, rounds_run))
        if tracker is not None:
            summary["faults"] = tracker.summary()
        return {"requests": requests, "rounds": history, "summary": summary,
                "state": state, "manager": mgr}
