"""The GoodSpeed serving engine: N draft servers + 1 verification server,
with REAL transformer models end-to-end (Algorithm 1 over actual logits).

Round structure (paper Fig. 1):
  (1) each draft server autoregressively samples S_i(t) tokens from its
      draft model (KV-cached decode steps);
  (2-3) drafts are batched into one ragged [N, S_max] verify batch;
  (4) the target model scores the chunk [pending_i, d_1..d_S] in ONE
      decode-chunk forward (positions len_i..len_i+S), and the verifier
      runs lossless rejection sampling (core.speculative.verify);
  (5) estimators update (Eqs. 3-4) and GOODSPEED-SCHED allocates S(t+1);
  (6) accepted tokens commit; caches roll back past rejected drafts.

Cache-consistency invariant: a model's cache always contains the committed
sequence EXCEPT the final committed token, which is the next chunk's first
input ("pending").  Rollback strategies:
  * attention/MLA caches — slot invalidation (kv_cache.rollback), O(1);
  * recurrent states (SSM/hybrid) — checkpoint-and-recompute: the engine
    snapshots the state before the chunk and, after verification, re-runs
    the accepted prefix only.  ``Rollback=recompute`` is correct for every
    architecture; slot rollback is the fast path for pure-attention stacks.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.estimator import EstimatorState, GoodputEstimator
from repro.core.latency import LatencyModel
from repro.core.scheduler import fixed_s, random_s, solve_threshold
from repro.core.speculative import verify
from repro.core.utility import UtilitySpec
from repro.models import Model
from repro.serving.kv_cache import AttnCache, MLACache, rollback

Array = jnp.ndarray


def _is_rollbackable(cfg: ModelConfig) -> bool:
    """Slot rollback works for full-attention stacks (incl. MLA).  Ring
    buffers overwrite old slots during the chunk and recurrent states are
    not invertible — those use checkpoint-and-recompute."""
    return set(cfg.layer_kinds) <= {"attn"}


def _cache_rollback(cache, keep_pos: Array):
    """Slot-invalidate every attention cache in the stack cache pytree."""
    def fix(c):
        if isinstance(c, (AttnCache, MLACache)):
            return rollback(c, keep_pos)
        return c
    return jax.tree.map(fix, cache,
                        is_leaf=lambda c: isinstance(c, (AttnCache, MLACache)))


class EngineState(NamedTuple):
    # sequences: committed tokens per server (host-side ragged bookkeeping)
    target_cache: object
    draft_cache: object
    pending: Array        # i32[N] last committed token (next chunk input)
    length: Array         # i32[N] committed length EXCLUDING pending
    est: EstimatorState
    S: Array              # i32[N] current allocation
    key: Array


class RoundStats(NamedTuple):
    S: np.ndarray
    accepted: np.ndarray
    realized: np.ndarray
    alpha_hat: np.ndarray
    goodput_est: np.ndarray
    utility: float
    wall: np.ndarray       # [total, receive, verify, send]
    emitted: np.ndarray    # [N, S_max+1] tokens, -1 padded


@dataclasses.dataclass(frozen=True)
class GoodSpeedEngine:
    draft_model: Model
    target_model: Model
    n_servers: int
    C: int
    s_max: int                     # per-server draft cap (latency bound)
    cache_len: int = 512
    policy: str = "goodspeed"      # goodspeed | fixed | random
    estimator: GoodputEstimator = GoodputEstimator()
    utility: UtilitySpec = UtilitySpec(alpha=1.0)
    latency: LatencyModel = LatencyModel()
    draft_temps: tuple = ()        # per-server draft temperature (heterogeneity)

    # ------------------------------------------------------------------
    def init(self, key: Array, prompts: list[np.ndarray],
             draft_params, target_params) -> EngineState:
        """Prefill both models on the per-server prompts."""
        n = self.n_servers
        assert len(prompts) == n
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((n, maxlen), np.int32)
        valid = np.zeros((n, maxlen), bool)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            valid[i, :len(p)] = True
        toks_j = jnp.asarray(toks)
        valid_j = jnp.asarray(valid)
        lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)

        # Prefill caches with all but the LAST prompt token of each row:
        # feeding token t writes slot t; "pending" = last prompt token.
        pend_idx = jnp.maximum(lengths - 1, 0)
        feed_valid = valid_j & (jnp.arange(maxlen)[None, :] < pend_idx[:, None])
        tcache = self.target_model.init_cache(n, self.cache_len)
        dcache = self.draft_model.init_cache(n, self.cache_len)
        t_out = self.target_model.forward(target_params, toks_j,
                                          mode="prefill", cache=tcache,
                                          chunk_valid=feed_valid)
        d_out = self.draft_model.forward(draft_params, toks_j,
                                         mode="prefill", cache=dcache,
                                         chunk_valid=feed_valid)
        pending = jnp.take_along_axis(toks_j, pend_idx[:, None], axis=1)[:, 0]
        return EngineState(
            target_cache=t_out.cache, draft_cache=d_out.cache,
            pending=pending, length=pend_idx,
            est=self.estimator.init(n),
            S=fixed_s(n, self.C), key=key)

    # ------------------------------------------------------------------
    def _draft(self, params, state: EngineState, key: Array):
        """Step (1): each server decodes s_max tokens (rows with S_i < s_max
        mask the tail).  Returns draft tokens, their q logits, updated cache."""
        n, s_cap = self.n_servers, self.s_max
        temps = jnp.asarray(self.draft_temps or (1.0,) * n, jnp.float32)

        def dec(carry, t):
            cache, tok, pos, key = carry
            key, k_s = jax.random.split(key)
            out = self.draft_model.forward(
                params, tok[:, None], mode="decode", cache=cache,
                positions=pos[:, None])
            logits = out.logits[:, 0, :]  # [N, Vp]
            logits = self._mask_vocab(logits, self.draft_model.cfg)
            # q := the ACTUAL sampling distribution (incl. temperature) —
            # rejection sampling is only lossless w.r.t. the true q.
            logits = logits / temps[:, None]
            nxt = jax.random.categorical(k_s, logits, axis=-1)
            return (out.cache, nxt.astype(jnp.int32), pos + 1, key), \
                (nxt.astype(jnp.int32), logits)

        (cache, _, _, _), (toks, qlogits) = jax.lax.scan(
            dec, (state.draft_cache, state.pending, state.length, key),
            jnp.arange(s_cap))
        # scan stacks time-first: [S, N] -> [N, S]
        return toks.swapaxes(0, 1), qlogits.swapaxes(0, 1), cache

    @staticmethod
    def _mask_vocab(logits: Array, cfg: ModelConfig) -> Array:
        if cfg.padded_vocab > cfg.vocab_size:
            pad = logits.shape[-1] - cfg.vocab_size
            mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,)),
                                    jnp.full((pad,), -1e30)])
            logits = logits + mask
        return logits

    # ------------------------------------------------------------------
    def _verify_chunk(self, params, state: EngineState, draft_toks: Array):
        """Step (4a): target scores [pending, d_1..d_{S-1}, d_S] in one
        decode-chunk; output j is the distribution of chunk position j+1."""
        n, s_cap = self.n_servers, self.s_max
        chunk = jnp.concatenate([state.pending[:, None], draft_toks], axis=1)
        in_draft = jnp.arange(s_cap)[None, :] < state.S[:, None]
        chunk_valid = jnp.concatenate(
            [jnp.ones((n, 1), bool), in_draft], axis=1)
        positions = state.length[:, None] + jnp.cumsum(
            chunk_valid.astype(jnp.int32), axis=1) - 1
        out = self.target_model.forward(
            params, chunk, mode="decode", cache=state.target_cache,
            positions=positions, chunk_valid=chunk_valid)
        p_logits = self._mask_vocab(out.logits, self.target_model.cfg)
        return p_logits, out.cache, in_draft

    # ------------------------------------------------------------------
    def run_round(self, state: EngineState, draft_params, target_params
                  ) -> tuple[EngineState, RoundStats]:
        key, k_draft, k_verify, k_sched, k_jit = jax.random.split(state.key, 5)
        cfg_t = self.target_model.cfg

        draft_toks, q_logits, draft_cache = self._draft(
            draft_params, state, k_draft)
        p_logits, target_cache, in_draft = self._verify_chunk(
            target_params, state, draft_toks)

        res = verify(k_verify, draft_toks, q_logits, p_logits, state.S)
        m = res.accepted                               # accepted drafts
        realized = res.num_emitted.astype(jnp.float32)

        # ---- commit / rollback -------------------------------------------
        new_length = state.length + m + 1              # commits m+1 tokens
        keep_pos = new_length                          # cache keeps < keep (pending excl.)
        if _is_rollbackable(cfg_t):
            target_cache = _cache_rollback(target_cache, keep_pos)
        else:
            target_cache = self._recompute_cache(
                self.target_model, target_params, state.target_cache,
                state.pending, draft_toks, m, state.length)
        if _is_rollbackable(self.draft_model.cfg):
            draft_cache = _cache_rollback(draft_cache, keep_pos)
        else:
            draft_cache = self._recompute_cache(
                self.draft_model, draft_params, state.draft_cache,
                state.pending, draft_toks, m, state.length)

        # ---- estimator + scheduler (steps 5-6) ----------------------------
        est = self.estimator.update(state.est, res.accept_ratio_sum,
                                    state.S, realized)
        if self.policy == "goodspeed":
            w = self.utility.grad(est.goodput)
            s_next = solve_threshold(
                est.alpha_hat, w, self.C,
                s_max=jnp.full((self.n_servers,), self.s_max, jnp.int32)).S
        elif self.policy == "fixed":
            s_next = jnp.minimum(fixed_s(self.n_servers, self.C), self.s_max)
        else:
            s_next = jnp.minimum(
                random_s(k_sched, self.n_servers, self.C), self.s_max)

        jitter = jax.random.uniform(k_jit, (self.n_servers,),
                                    minval=-1.0, maxval=1.0)
        total, (rt, vt, st) = self.latency.round_time(
            state.S, res.num_emitted, cfg_t.vocab_size, jitter)

        new_state = EngineState(
            target_cache=target_cache, draft_cache=draft_cache,
            pending=res.extra_token, length=new_length, est=est, S=s_next,
            key=key)
        stats = RoundStats(
            S=np.asarray(state.S), accepted=np.asarray(m),
            realized=np.asarray(realized), alpha_hat=np.asarray(est.alpha_hat),
            goodput_est=np.asarray(est.goodput),
            utility=float(self.utility.value(est.goodput)),
            wall=np.asarray(jnp.stack([total, rt, vt, st])),
            emitted=np.asarray(res.emitted))
        return new_state, stats

    # ------------------------------------------------------------------
    def _recompute_cache(self, model: Model, params, checkpoint_cache,
                         pending: Array, draft_toks: Array, m: Array,
                         length: Array):
        """Recompute strategy: advance the PRE-CHUNK cache by the accepted
        prefix [pending, d_1..d_m] only (masked chunk)."""
        n, s_cap = draft_toks.shape
        chunk = jnp.concatenate([pending[:, None], draft_toks], axis=1)
        valid = jnp.arange(s_cap + 1)[None, :] <= m[:, None]
        positions = length[:, None] + jnp.arange(s_cap + 1)[None, :]
        out = model.forward(params, chunk, mode="decode",
                            cache=checkpoint_cache, positions=positions,
                            chunk_valid=valid)
        return out.cache

    # ------------------------------------------------------------------
    def serve(self, key: Array, prompts: list[np.ndarray], draft_params,
              target_params, rounds: int) -> list[RoundStats]:
        state = self.init(key, prompts, draft_params, target_params)
        history = []
        for _ in range(rounds):
            state, stats = self.run_round(state, draft_params, target_params)
            history.append(stats)
        return history
