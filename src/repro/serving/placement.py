"""Pluggable request-placement policies for global admission.

The paper's fairness machinery (GOODSPEED-SCHED, §III-C) allocates the
verification budget fairly across draft servers — but it can only be fair
over the requests that actually reach those servers.  With static
per-server affinity a hot server queues while its neighbours idle, which
is precisely the goodput loss proportional fairness exists to prevent.
This module decides, at admission time, WHICH draft server a newly
arrived request joins:

* ``static``  — honour the request's submitted server (pre-placement
  behaviour, kept as the equivalence baseline: under it the engine must
  emit byte-identical accepted-token sequences to the per-server-FIFO
  engine — ``tests/test_placement.py``);
* ``jsq``     — join-shortest-queue by queued token demand plus the
  active request's remaining cap;
* ``goodput`` — score each server by its estimated acceptance rate
  ``alpha_hat`` (``repro.core.estimator``) and, under paged-KV block
  pressure, the pool's free blocks: the request joins the server with the
  fewest expected ROUNDS to completion, i.e. placement maximizes expected
  accepted tokens per verification round.  When every estimate still sits
  at ``alpha_init`` (cold start) the scores are uniform in ``alpha`` and
  the choice degrades exactly to ``jsq``.

Policies decide the SERVER only: under draft lanes
(``GoodSpeedEngine(lanes=R)``) the manager seats the placed request into
the chosen server's lowest free lane, and the view's signals are
lane-aware server aggregates (``active_remaining`` sums the lanes' caps;
the engine's free-block reserve counts every active lane's chunk
headroom).

Policies are host-side and pure: ``place`` never mutates the manager; the
``RequestManager`` owns the queues and updates the view's running load as
a burst of arrivals is placed, so successive placements see each other.
The shared paged-KV admission gate (``fits_pool``) lives here too: a
request whose prompt cannot fit the free block list is DEFERRED (stays
queued, ages its wait clock) instead of surfacing a
``PoolExhaustedError`` from the admission prefill — every policy gets
that behaviour, not just ``goodput``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serving.kv_cache import PoolExhaustedError, blocks_for

# alpha_hat entries within this of alpha_init count as "never observed":
# the estimator holds unobserved servers exactly at alpha_init, so cold
# detection is an equality test up to float noise.
_COLD_TOL = 1e-6


@dataclasses.dataclass
class PlacementView:
    """Per-server serving state a policy may consult (host-side numpy).

    ``queue_load`` is mutated by the manager as arrivals are placed
    (``note_placed``) and ``free_blocks`` as admissions claim pool blocks
    (``note_admitted``), so one view serves a whole admission call.
    """

    queue_load: np.ndarray          # i64[N] queued token demand per server
    # i32[N] remaining caps of the server's ACTIVE requests (summed over
    # its lanes when the engine runs lanes > 1 — placement decides the
    # server; the manager picks the lane)
    active_remaining: np.ndarray
    alpha_hat: Optional[np.ndarray] = None   # f32[N] estimator state
    alpha_init: float = 0.5
    s_max: int = 4                  # per-server draft cap (mu horizon)
    # min free blocks over the paged pools (None = static caches, no gate)
    free_blocks: Optional[int] = None
    # min TOTAL pool capacity in blocks: distinguishes "temporarily full"
    # (defer, blocks free as requests retire) from "can never fit" (raise)
    total_blocks: Optional[int] = None
    block_size: int = 16
    # bool[N] server availability (health tracker: not DOWN).  None = all
    # available.  Lazy policies never choose an unavailable server; the
    # manager additionally gates seating, so even a static binding cannot
    # land on a down server.
    available: Optional[np.ndarray] = None

    def backlog(self) -> np.ndarray:
        """Token demand ahead of a new arrival on each server."""
        return self.queue_load + self.active_remaining

    def masked(self, score: np.ndarray) -> np.ndarray:
        """Push unavailable servers' scores to +inf so argmin never
        elects a DOWN server (when every server is down, the manager's
        seating gate holds the request regardless of the argmin)."""
        if self.available is None:
            return score
        return np.where(self.available, score.astype(np.float64), np.inf)

    def blocks_need(self, request) -> int:
        """Pool blocks ``request`` needs through its FIRST serving round:
        the admission prefill's context (minus the pending token, as in
        the engine's ``_admit_rows_paged`` pre-check) PLUS the round's
        verify chunk (pending + up to s_max drafts).  Without the chunk
        headroom an exactly-fitting admission would pass the gate and
        then trip the sticky ``alloc_failed`` mid-round — the crash the
        deferral exists to prevent.  The engine additionally subtracts
        the ACTIVE rows' same-round growth from the view's
        ``free_blocks`` (``_placement_view``); growth beyond the current
        round is the engine's ``_check_pool_health`` backstop."""
        feed = max(0, len(request.prompt) + len(request.generated) - 1)
        return blocks_for(feed + self.s_max + 1, self.block_size)

    def note_placed(self, request, server: int) -> None:
        self.queue_load[server] += request.remaining

    def note_admitted(self, request, server: int) -> None:
        # the request moves queue -> active slot: shift its demand too, so
        # backlog() stays consistent for any later reader of this view
        self.queue_load[server] = max(
            0, self.queue_load[server] - request.remaining)
        self.active_remaining[server] += request.remaining
        if self.free_blocks is not None:
            self.free_blocks -= self.blocks_need(request)


def fits_pool(request, view: Optional[PlacementView]) -> bool:
    """Paged-KV admission gate: False defers the admission (request stays
    queued, blocks free as other requests retire) instead of letting the
    engine's prefill pre-check raise ``PoolExhaustedError``.  Static
    caches (``free_blocks`` None) always fit.  A request whose prompt
    exceeds the TOTAL pool capacity can never be seated by waiting —
    that is a misconfiguration, and deferring it would silently livelock
    the drain, so it raises."""
    if view is None or view.free_blocks is None:
        return True
    need = view.blocks_need(request)
    if view.total_blocks is not None and need > view.total_blocks:
        raise PoolExhaustedError(
            f"request {request.request_id} needs {need} KV blocks but the "
            f"pool only has {view.total_blocks} in total — admission could "
            f"never succeed; grow kv_num_blocks or shorten the prompt")
    return need <= view.free_blocks


class PlacementPolicy:
    """``place(request, view) -> server`` — pure, host-side.

    ``binds_on_arrival``: True means a request commits to its server the
    moment it is seen (static affinity: the hint IS the decision, and the
    per-server FIFO order must be preserved).  False means the request
    stays in the global arrival queue until a slot can actually seat it,
    so the decision always runs against LIVE state — an early binding
    would recreate the hot-server-queues-while-neighbours-idle pathology
    whenever the bound server turns out to be the slow one."""

    name = "?"
    binds_on_arrival = False

    def place(self, request, view: PlacementView) -> int:
        raise NotImplementedError


class StaticPlacement(PlacementPolicy):
    """The request joins the server it was submitted to (per-server FIFO
    affinity — the pre-placement engine's behaviour)."""

    name = "static"
    binds_on_arrival = True

    def place(self, request, view: PlacementView) -> int:
        hint = getattr(request, "server_hint", None)
        if hint is None:
            raise ValueError("static placement needs a server hint "
                             "(submit(server, request))")
        return int(hint)


class JSQPlacement(PlacementPolicy):
    """Join-shortest-queue: minimal queued-token demand + active remaining
    cap; ties break to the lowest server index (deterministic)."""

    name = "jsq"

    def place(self, request, view: PlacementView) -> int:
        return int(np.argmin(view.masked(view.backlog())))


class GoodputPlacement(PlacementPolicy):
    """Minimize expected rounds-to-completion using the live estimates.

    Expected accepted tokens per round on server i at draft cap ``s_max``
    is mu(s_max; alpha_i) = (1 - alpha^(s_max+1)) / (1 - alpha) (paper
    §III-B), so placing the request on server i costs roughly

        (backlog_i + request.remaining) / mu_i      rounds.

    Under paged-KV block pressure (the pool cannot hold this request's
    prompt right now) the request additionally waits for backlog ahead of
    it to retire and free blocks, so the existing backlog is counted
    twice.  With every alpha_hat still at ``alpha_init`` (cold start) the
    mu_i are all equal and argmin reduces exactly to JSQ's choice.
    """

    name = "goodput"

    def __init__(self):
        self._jsq = JSQPlacement()

    @staticmethod
    def _mu(alpha: np.ndarray, s_max: int) -> np.ndarray:
        a = np.clip(np.asarray(alpha, np.float64), 1e-6, 1.0 - 1e-6)
        return (1.0 - a ** (s_max + 1)) / (1.0 - a)

    def place(self, request, view: PlacementView) -> int:
        a = view.alpha_hat
        if a is None or np.all(np.abs(np.asarray(a) - view.alpha_init)
                               < _COLD_TOL):
            return self._jsq.place(request, view)
        mu = self._mu(a, view.s_max)
        backlog = view.backlog().astype(np.float64)
        score = (backlog + request.remaining) / mu
        if view.free_blocks is not None \
                and view.free_blocks < view.blocks_need(request):
            score = score + backlog / mu    # wait for blocks to free
        return int(np.argmin(view.masked(score)))


_POLICIES = {p.name: p for p in (StaticPlacement, JSQPlacement,
                                 GoodputPlacement)}


def make_placement(policy) -> PlacementPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if isinstance(policy, PlacementPolicy):
        return policy
    if policy not in _POLICIES:
        raise ValueError(f"unknown placement policy {policy!r}; "
                         f"choose from {sorted(_POLICIES)}")
    return _POLICIES[policy]()
