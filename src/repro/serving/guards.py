"""Runtime jit-discipline guards: retrace budgets and the transfer fence.

Static analysis (``repro.analysis.jaxlint``) proves the SHAPE of the
code; these guards prove the RUN.  Two complementary contracts:

* **Retrace budget** — ``TraceGuard`` promotes the
  ``GoodSpeedEngine.round_trace_counts()`` telemetry (compiled-variant
  count per round-phase jit, previously asserted only in
  ``benchmarks/serve_requests.py``) into an enforced invariant: between
  ``__enter__`` and each ``check()`` every phase may add at most
  ``budget`` compiled variants.  One bucket shape compiles each phase
  exactly once, so ``budget=1`` is the steady-state contract; a fault
  plan introduces one extra variant per phase (the traced-``RoundFaults``
  graph, shared by every faulted round), hence ``budget=2`` under
  faults.  ``GoodSpeedEngine.serve_requests(strict_compile=True)`` wires
  this around the production loop and checks after every round, so the
  offending round is named in the error instead of being discovered
  rounds later in a benchmark assert.

* **Transfer fence** — ``jax.transfer_guard("disallow")`` around
  ``GoodSpeedEngine.dispatch_round`` (tests/test_trace_guard.py).  Every
  host->device movement in the dispatch path must be EXPLICIT
  (``jnp.asarray`` / ``jax.device_put``); a raw numpy array or Python
  scalar reaching a warm jit is an implicit transfer and raises under
  the fence.  Host work deliberately OUTSIDE the fence: placement views
  and admission (host-side orchestration between rounds), and the
  ``RoundStats`` materialization in ``run_round`` (the round's one
  sanctioned device->host sync point).  docs/STATIC_ANALYSIS.md has the
  full region map.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


class RetraceError(RuntimeError):
    """A round-phase jit exceeded its compile budget (retrace in the
    serving loop — every server stalls for a full XLA compile)."""


@dataclasses.dataclass
class TraceGuard:
    """Context manager enforcing the one-compile-per-phase-per-bucket
    contract over any object exposing ``round_trace_counts() -> dict``
    (the ``GoodSpeedEngine`` protocol).

    ``budget`` is the number of NEW compiled variants each phase may
    acquire while the guard is active — 1 for a fixed-bucket serve, 2
    when a fault plan adds the traced-faults variant.  ``check()`` may
    be called any number of times (serve_requests calls it per round);
    ``__exit__`` runs a final check unless an exception is already
    propagating.
    """
    engine: object
    budget: int = 1
    baseline: Optional[dict] = None

    def __enter__(self) -> "TraceGuard":
        self.baseline = dict(self.engine.round_trace_counts())
        return self

    def check(self, where: str = "") -> dict:
        """Raise ``RetraceError`` if any phase compiled more than
        ``budget`` new variants since ``__enter__``; returns the current
        counts otherwise."""
        assert self.baseline is not None, \
            "TraceGuard.check() before __enter__"
        counts = self.engine.round_trace_counts()
        over = {ph: (c, self.baseline.get(ph, 0)) for ph, c in counts.items()
                if c - self.baseline.get(ph, 0) > self.budget}
        if over:
            detail = ", ".join(
                f"{ph}: {base}->{c} compiles (budget +{self.budget})"
                for ph, (c, base) in sorted(over.items()))
            at = f" at {where}" if where else ""
            raise RetraceError(
                f"round-phase retrace{at}: {detail}.  A phase recompiled "
                f"mid-serve — check for shape drift in the round inputs, "
                f"weak dtypes, or a fresh jit in the round path "
                f"(jaxlint JL002).")
        return counts

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.check("exit")
        return False
