"""Synthetic data pipeline: token streams for training + a non-stationary
prompt workload for serving (standing in for the paper's 8 datasets).

The paper assigns a distinct dataset per draft server (Alpaca, CNN/DailyMail,
GSM8K, SPIDER, ...) giving heterogeneous, drifting acceptance rates.  We
model each dataset as a *domain*: a Zipf token distribution with its own
random permutation, mixing temperature, and prompt-length profile; domains
drift over time (topic shifts) which is what makes alpha_i(t) non-stationary.
Deterministic given seed — reproducible experiments without downloads.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# The paper's eight evaluation datasets (§IV-A2) as named synthetic domains.
PAPER_DATASETS = ("alpaca", "awesome-prompts", "cnn-dailymail", "openorca",
                  "chatbot-arena", "gsm8k", "spider", "hle")

_PROFILES = {
    # name: (zipf_a, mean_prompt_len, base_alpha, alpha_drift)
    "alpaca": (1.2, 24, 0.80, 0.05),
    "awesome-prompts": (1.1, 32, 0.75, 0.05),
    "cnn-dailymail": (1.3, 96, 0.70, 0.08),
    "openorca": (1.15, 48, 0.65, 0.10),
    "chatbot-arena": (1.05, 28, 0.60, 0.12),
    "gsm8k": (1.25, 40, 0.50, 0.10),
    "spider": (1.4, 36, 0.45, 0.08),
    "hle": (1.1, 64, 0.35, 0.15),
}


@dataclasses.dataclass(frozen=True)
class SyntheticDomain:
    name: str
    vocab: int
    seed: int

    def _profile(self):
        return _PROFILES.get(self.name, (1.2, 32, 0.6, 0.1))

    def zipf_logits(self) -> np.ndarray:
        a, _, _, _ = self._profile()
        rng = np.random.default_rng(zlib.crc32(self.name.encode()) + self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        probs = ranks ** (-a)
        probs /= probs.sum()
        return np.log(probs[rng.permutation(self.vocab)]).astype(np.float32)

    def sample_prompt(self, rng: np.random.Generator) -> np.ndarray:
        _, mean_len, _, _ = self._profile()
        length = max(4, int(rng.poisson(mean_len)))
        logits = self.zipf_logits()
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return rng.choice(self.vocab, size=length, p=p).astype(np.int32)

    def alpha_trajectory(self, rounds: int) -> np.ndarray:
        """Ground-truth acceptance-rate drift used by analytic simulators:
        base +/- sinusoidal topic drift + OU noise, clipped to (0.05, 0.98)."""
        _, _, base, drift = self._profile()
        rng = np.random.default_rng(zlib.crc32(self.name.encode()) + self.seed + 1)
        t = np.arange(rounds)
        period = rng.integers(150, 400)
        wave = drift * np.sin(2 * np.pi * t / period + rng.uniform(0, 6.28))
        ou = np.zeros(rounds)
        for i in range(1, rounds):
            ou[i] = 0.95 * ou[i - 1] + 0.02 * rng.standard_normal()
        return np.clip(base + wave + ou, 0.05, 0.98).astype(np.float32)


def make_workload(n_servers: int, vocab: int, rounds: int, seed: int = 0):
    """Per-server (domain, alpha trajectory): server i gets dataset i mod 8."""
    domains = [SyntheticDomain(PAPER_DATASETS[i % len(PAPER_DATASETS)],
                               vocab, seed) for i in range(n_servers)]
    alphas = np.stack([d.alpha_trajectory(rounds) for d in domains], axis=1)
    return domains, jnp.asarray(alphas)  # [rounds, N]


def token_stream(vocab: int, batch: int, seq: int, steps: int, seed: int = 0,
                 n_domains: int = 4):
    """Deterministic LM training batches: each element drawn from one of
    ``n_domains`` Zipf domains (so the model has learnable structure)."""
    rng = np.random.default_rng(seed)
    doms = [SyntheticDomain(PAPER_DATASETS[i % len(PAPER_DATASETS)], vocab,
                            seed + i) for i in range(n_domains)]
    tables = []
    for d in doms:
        logits = d.zipf_logits()
        p = np.exp(logits - logits.max())
        tables.append(p / p.sum())
    for _ in range(steps):
        dom_idx = rng.integers(0, n_domains, size=batch)
        toks = np.stack([
            rng.choice(vocab, size=seq, p=tables[k]) for k in dom_idx])
        yield {"tokens": jnp.asarray(toks, jnp.int32)}
