"""jaxlint (repro.analysis.jaxlint): fixture tests per rule family —
each seeds a violation the rule must catch AND shows the corrected form
it must accept — plus the suppression contract and the self-hosted gate
(the whole of src/ lints clean; this is the `make lint-check` / CI
contract as a tier-1 test).
"""
import textwrap

import pytest

from repro.analysis.jaxlint import lint_paths, lint_source


def codes(src, select=None):
    return [f.code for f in lint_source(textwrap.dedent(src),
                                        codes=select)]


# ---------------------------------------------------------------------------
# JL001 donation-after-use
# ---------------------------------------------------------------------------

class TestDonation:
    def test_read_after_donate_flagged(self):
        src = """
        import jax
        step = jax.jit(lambda s, x: s, donate_argnums=(0,))

        def loop(state, xs):
            out = step(state, xs)
            return state.cache        # read of donated binding
        """
        assert codes(src) == ["JL001"]

    def test_donate_and_rebind_accepted(self):
        src = """
        import jax
        step = jax.jit(lambda s, x: s, donate_argnums=(0,))

        def loop(state, xs):
            state = step(state, xs)   # sanctioned: rebinding clears
            return state.cache
        """
        assert codes(src) == []

    def test_method_donator_via_setattr(self):
        src = """
        import jax

        class Engine:
            def __post_init__(self):
                object.__setattr__(
                    self, "_round_fn",
                    jax.jit(self._round, donate_argnums=(0,)))

            def run(self, state, params):
                new_state, stats = self._round_fn(state, params)
                bad = state.pending      # donated buffers are gone
                return new_state, stats
        """
        assert codes(src) == ["JL001"]

    def test_early_return_branch_does_not_leak(self):
        # the engine's dispatch idiom: the sync branch donates and
        # RETURNS; the overlap path after the `if` reads state freely
        src = """
        import jax
        step = jax.jit(lambda s, x: s, donate_argnums=(0,))

        def dispatch(state, xs, sync):
            if sync:
                return step(state, xs)
            return state.pending + xs
        """
        assert codes(src) == []

    def test_loop_wraparound_read_flagged(self):
        src = """
        import jax
        step = jax.jit(lambda s, x: s, donate_argnums=(0,))

        def loop(state, xs):
            for x in xs:
                y = state.pending     # round 2 reads round 1's donation
                out = step(state, x)
            return out
        """
        assert "JL001" in codes(src)

    def test_transitive_donation_through_wrapper(self):
        # run_round forwards its state into the donating jit; a caller
        # of run_round therefore also donates
        src = """
        import jax
        step = jax.jit(lambda s, x: s, donate_argnums=(0,))

        def run_round(state, x):
            return step(state, x)

        def serve(state, xs):
            out = run_round(state, xs)
            return state.cache
        """
        assert "JL001" in codes(src)


# ---------------------------------------------------------------------------
# JL002 jit-in-hot-scope
# ---------------------------------------------------------------------------

class TestJitScope:
    def test_jit_inside_plain_function_flagged(self):
        src = """
        import jax

        def round_step(params, x):
            f = jax.jit(lambda p, v: v)   # fresh cache every call
            return f(params, x)
        """
        assert codes(src) == ["JL002"]

    def test_module_level_and_post_init_accepted(self):
        src = """
        import jax
        g = jax.jit(lambda x: x)

        class Engine:
            def __post_init__(self):
                object.__setattr__(self, "_fn", jax.jit(self._core))

                def make(model):          # factory nested in init scope
                    return jax.jit(lambda p: model(p))
                object.__setattr__(self, "_pre", make(self))
        """
        assert codes(src) == []

    def test_partial_jit_decorator_in_function_flagged(self):
        src = """
        import functools
        import jax

        def build(x):
            @functools.partial(jax.jit, static_argnums=(1,))
            def inner(v, k):
                return v * k
            return inner(x, 2)
        """
        assert codes(src) == ["JL002"]

    def test_suppression_with_justification(self):
        src = """
        import jax

        def main():
            # jaxlint: disable=JL002 — CLI entry, built once per process
            f = jax.jit(lambda x: x)
            return f(1)
        """
        assert codes(src) == []


# ---------------------------------------------------------------------------
# JL003 unhashable-static-arg
# ---------------------------------------------------------------------------

class TestStaticArgs:
    def test_dict_literal_at_static_position_flagged(self):
        src = """
        import jax
        f = jax.jit(lambda x, cfg: x, static_argnums=(1,))

        def call(x):
            return f(x, {"s_max": 4})     # unhashable cache key
        """
        assert codes(src) == ["JL003"]

    def test_tuple_at_static_position_accepted(self):
        src = """
        import jax
        f = jax.jit(lambda x, cfg: x, static_argnums=(1,))

        def call(x):
            return f(x, ("s_max", 4))
        """
        assert codes(src) == []

    def test_static_argnames_keyword_flagged(self):
        src = """
        import jax
        f = jax.jit(lambda x, shapes=None: x, static_argnames=("shapes",))

        def call(x):
            return f(x, shapes=[4, 8])
        """
        assert codes(src) == ["JL003"]

    def test_mutable_default_on_jit_root_flagged(self):
        src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("buckets",))
        def step(x, buckets=[8, 16]):
            return x
        """
        assert codes(src) == ["JL003"]


# ---------------------------------------------------------------------------
# JL004 traced-python-branch
# ---------------------------------------------------------------------------

class TestTracedBranch:
    def test_if_on_traced_value_flagged(self):
        src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            if x.sum() > 0:               # concretizes a tracer
                return x
            return -x
        """
        assert codes(src) == ["JL004"]

    def test_where_accepted(self):
        src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.where(x.sum() > 0, x, -x)
        """
        assert codes(src) == []

    def test_is_none_and_key_membership_exempt(self):
        # structure checks resolved at trace time: `faults is None`
        # (engine round) and `"prefix_embeds" in batch` (pytree keys)
        src = """
        import jax

        @jax.jit
        def step(batch, faults=None):
            y = batch["tokens"]
            if faults is not None:
                y = y * faults["slow"]
            if "prefix_embeds" in batch:
                y = y + batch["prefix_embeds"]
            return y
        """
        assert codes(src) == []

    def test_shape_branch_exempt(self):
        src = """
        import jax

        @jax.jit
        def step(x):
            if x.shape[0] > 1:            # static metadata: fine
                return x
            return x[:1]
        """
        assert codes(src) == []

    def test_while_in_reachable_helper_flagged(self):
        # hotness propagates through the same-module call graph
        src = """
        import jax
        import jax.numpy as jnp

        def helper(x):
            while x[0] > 0:
                x = x - 1
            return x

        @jax.jit
        def step(x):
            return helper(x)
        """
        assert codes(src) == ["JL004"]


# ---------------------------------------------------------------------------
# JL005 host-sync-in-jit
# ---------------------------------------------------------------------------

class TestHostSync:
    def test_item_flagged(self):
        src = """
        import jax

        @jax.jit
        def step(x):
            return x / x.sum().item()
        """
        assert codes(src) == ["JL005"]

    def test_numpy_on_traced_flagged(self):
        src = """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return np.asarray(x) * 2
        """
        assert codes(src) == ["JL005"]

    def test_concretizer_and_fstring_flagged(self):
        src = """
        import jax

        @jax.jit
        def step(x):
            k = int(x[0])
            msg = f"budget={x}"
            return x + k
        """
        got = codes(src)
        assert got.count("JL005") == 2

    def test_host_path_not_flagged(self):
        # the same operations OUTSIDE the jit call tree are the
        # sanctioned materialization pattern (engine run_round)
        src = """
        import numpy as np

        def materialize(raw):
            return np.asarray(raw), float(raw[0])
        """
        assert codes(src) == []

    def test_jnp_equivalent_accepted(self):
        src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.asarray(x) / jnp.sum(x)
        """
        assert codes(src) == []


# ---------------------------------------------------------------------------
# JL006 sticky-flag-overwrite
# ---------------------------------------------------------------------------

class TestStickyFlags:
    def test_plain_replace_flagged(self):
        src = """
        def commit(cache, failed):
            return cache._replace(alloc_failed=failed)   # drops history
        """
        assert codes(src) == ["JL006"]

    def test_accumulation_accepted(self):
        src = """
        def commit(cache, failed):
            return cache._replace(
                alloc_failed=cache.alloc_failed | failed)
        """
        assert codes(src) == []

    def test_derived_local_accepted(self):
        src = """
        import jax.numpy as jnp

        def commit(cache, needs, cand, p):
            failed = cache.alloc_failed | jnp.any(needs & (cand >= p))
            return cache._replace(alloc_failed=failed)
        """
        assert codes(src) == []

    def test_sanctioned_reset_accepted(self):
        src = """
        import jax.numpy as jnp

        def reset_rows(cache, rows):
            return cache._replace(
                overflowed=jnp.where(rows, False, cache.overflowed))

        def fresh(cache):
            return cache._replace(
                overflowed=jnp.zeros(cache.overflowed.shape, bool),
                alloc_failed=False)
        """
        assert codes(src) == []

    def test_snapshot_restore_param_name_convention(self):
        # discard_tail restore: a parameter literally named after the
        # flag is the sanctioned rollback spelling
        src = """
        def restore(cache, alloc_failed, overflowed):
            return cache._replace(alloc_failed=alloc_failed,
                                  overflowed=overflowed)
        """
        assert codes(src) == []

    def test_attribute_assign_flagged(self):
        src = """
        def poke(cache, x):
            cache.overflowed = x
            return cache
        """
        assert codes(src) == ["JL006"]


# ---------------------------------------------------------------------------
# driver: suppression, selection, syntax errors, self-hosting
# ---------------------------------------------------------------------------

class TestDriver:
    def test_suppression_on_line_and_line_above(self):
        src = """
        import jax

        def f(x):
            g = jax.jit(lambda v: v)  # jaxlint: disable=JL002 — run-once
            # jaxlint: disable=JL002 — run-once
            h = jax.jit(lambda v: v)
            return g(x) + h(x)
        """
        assert codes(src) == []

    def test_suppression_is_code_specific(self):
        src = """
        import jax

        def f(x):
            g = jax.jit(lambda v: v)  # jaxlint: disable=JL005
            return g(x)
        """
        assert codes(src) == ["JL002"]

    def test_select_filters_families(self):
        src = """
        import jax

        def f(x):
            g = jax.jit(lambda v: v)
            return g(x)
        """
        assert codes(src, select=["JL005"]) == []
        assert codes(src, select=["JL002"]) == ["JL002"]

    def test_syntax_error_is_jl000(self):
        assert codes("def f(:\n    pass") == ["JL000"]

    def test_finding_format(self):
        fs = lint_source("import jax\n\ndef f(x):\n"
                         "    return jax.jit(lambda v: v)(x)\n",
                         path="m.py")
        assert len(fs) == 1
        assert fs[0].format().startswith("m.py:4:")
        assert "JL002" in fs[0].format()

    def test_cli_exit_codes(self, tmp_path):
        from repro.analysis.jaxlint.core import main
        bad = tmp_path / "bad.py"
        bad.write_text("import jax\n\ndef f(x):\n"
                       "    return jax.jit(lambda v: v)(x)\n")
        ok = tmp_path / "ok.py"
        ok.write_text("x = 1\n")
        assert main([str(ok)]) == 0
        assert main([str(bad)]) == 1
        assert main([str(bad), "--select", "jl005"]) == 0


def test_self_hosted_src_is_clean():
    """The CI gate: the entire src/ tree lints at zero findings (every
    violation fixed or carrying a justified inline suppression)."""
    findings = lint_paths(["src"])
    assert findings == [], "\n".join(f.format() for f in findings)
