"""SPMD numeric equivalence: sharded execution == single-device execution.

The dry-run proves lowering/compiling; these tests prove the sharded
programs compute the SAME numbers (subprocess: forced host device count
must be set before jax initializes).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        use_sharding, tree_shardings,
                                        CACHE_AXES)
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.training.optimizer import AdamW
from repro.training.train_state import init_train_state, make_train_step

mesh = make_host_mesh(2, 4)

# ---- decode equivalence (qwen3 family, GQA + qk-norm) ---------------------
cfg = get_reduced("qwen3-8b", num_layers=2, d_model=64, num_heads=8,
                  num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, L = 4, 32
cache = model.init_cache(B, L)
toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
pos = jnp.zeros((B, 1), jnp.int32)

ref = model.forward(params, toks, mode="decode", cache=cache,
                    positions=pos).logits

with mesh, use_sharding(mesh, SERVE_RULES) as ctx:
    p_sh = tree_shardings(ctx, jax.eval_shape(lambda: model.init(
        jax.random.PRNGKey(0))))
    c_sh = tree_shardings(ctx, jax.eval_shape(lambda: model.init_cache(B, L)),
                          CACHE_AXES)
    fn = jax.jit(lambda p, t, po, c: model.forward(
        p, t, mode="decode", cache=c, positions=po).logits,
        in_shardings=(p_sh, ctx.sharding(("batch", None), (B, 1)),
                      ctx.sharding(("batch", None), (B, 1)), c_sh))
    out = fn(params, toks, pos, cache)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-3, f"decode SPMD mismatch {err}"
print("decode-equivalence OK", err)

# ---- train-step equivalence ------------------------------------------------
opt = AdamW(learning_rate=1e-3, warmup_steps=0, schedule="constant")
state = init_train_state(model, opt, jax.random.PRNGKey(2))
step = make_train_step(model, opt, remat=True)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0,
                                      cfg.vocab_size)}
_, m_ref = step(state, batch)

with mesh, use_sharding(mesh, TRAIN_RULES) as ctx:
    from repro.training.optimizer import AdamWState
    from repro.training.train_state import TrainState
    p_sh = tree_shardings(ctx, jax.eval_shape(lambda: model.init(
        jax.random.PRNGKey(2))))
    st_sh = TrainState(params=p_sh, opt=AdamWState(
        step=ctx.sharding((), ()), mu=p_sh, nu=p_sh))
    b_sh = {"tokens": ctx.sharding(("batch", None), (4, 32))}
    fn = jax.jit(step, in_shardings=(st_sh, b_sh))
    _, m_spmd = fn(state, batch)
d = abs(float(m_ref["loss"]) - float(m_spmd["loss"]))
assert d < 1e-3, f"train SPMD loss mismatch {d}"
print("train-equivalence OK", d)
"""


@pytest.mark.slow
@pytest.mark.parametrize("name", ["spmd"])
def test_spmd_numeric_equivalence(name, tmp_path):
    script = tmp_path / "spmd_check.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "decode-equivalence OK" in out.stdout
    assert "train-equivalence OK" in out.stdout
