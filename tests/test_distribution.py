"""Distribution-layer tests: sharding-rule derivation + dry-run integration.

The dry-run integration tests run in subprocesses because the forced host
device count must be set before jax initializes.
"""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        ShardingContext, tree_specs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    """Just enough of a Mesh for spec derivation tests (no devices)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSpecDerivation:
    def setup_method(self):
        self.ctx = ShardingContext(FakeMesh({"data": 16, "model": 16}),
                                   TRAIN_RULES)

    def test_divisibility_guard(self):
        # 8 heads on a 16-way model axis -> replicated
        assert self.ctx.spec(("fsdp", "heads", None), (512, 8, 64)) \
            == P("data")  # trailing Nones trimmed
        # 32 heads -> sharded
        assert self.ctx.spec(("fsdp", "heads", None), (4096, 32, 128)) \
            == P("data", "model")

    def test_axis_used_once(self):
        # seq grabs model first; vocab then falls back to replicated
        ctx = ShardingContext(FakeMesh({"data": 16, "model": 16}),
                              dict(SERVE_RULES, seq="model"))
        spec = ctx.spec(("batch", "seq", "vocab"), (32, 32768, 151936))
        assert spec == P("data", "model")

    def test_pod_axis_dropped_without_pod(self):
        ctx = ShardingContext(FakeMesh({"data": 16, "model": 16}),
                              TRAIN_RULES)
        assert ctx.rules["batch"] == "data"
        ctx3 = ShardingContext(
            FakeMesh({"pod": 2, "data": 16, "model": 16}), TRAIN_RULES)
        assert ctx3.rules["batch"] == ("pod", "data")
        assert ctx3.spec(("batch", None), (256, 4096)) == P(("pod", "data"))

    def test_param_tree_mapping(self):
        import jax.numpy as jnp
        tree = {"stack": {"scan": {"slot0": {
            "attn": {"wq": jax.ShapeDtypeStruct((24, 4096, 32, 128),
                                                jnp.bfloat16)},
            "norm1": {"scale": jax.ShapeDtypeStruct((24, 4096),
                                                    jnp.bfloat16)},
        }}}}
        specs = tree_specs(self.ctx, tree)
        wq = specs["stack"]["scan"]["slot0"]["attn"]["wq"]
        assert wq == P(None, "data", "model")  # scan axis replicated
        assert specs["stack"]["scan"]["slot0"]["norm1"]["scale"] == P()


SMOKE_COMBOS = [
    ("olmo-1b", "decode_32k"),
    ("qwen3-moe-235b-a22b", "decode_32k"),
    ("recurrentgemma-9b", "train_4k"),
    ("whisper-base", "decode_32k"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", SMOKE_COMBOS)
def test_dryrun_debug_mesh(arch, shape, tmp_path):
    """lower+compile on a forced-8-host-device (2,4) mesh: proves the
    sharding config is coherent (full 512-device run is launch/dryrun.py)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--debug-mesh", "2,4", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    rec = json.loads(files[0].read_text())
    assert rec["status"] == "ok"
    assert rec["flops"] > 0
    assert rec["devices"] == 8
