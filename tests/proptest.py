"""Minimal property-based testing harness (no `hypothesis` wheel offline).

Provides seeded random-case sweeps with the same spirit: a decorated test
runs N generated cases; on failure the failing case's seed and drawn values
are reported so the case is exactly reproducible.
"""
from __future__ import annotations


import numpy as np


class Draw:
    """Value generator bound to one case's RNG."""

    def __init__(self, rng: np.random.Generator):
        self.rng = rng
        self.trace: list = []

    def _rec(self, name, v):
        self.trace.append((name, v))
        return v

    def integers(self, lo, hi):
        return self._rec("integers", int(self.rng.integers(lo, hi + 1)))

    def floats(self, lo, hi):
        return self._rec("floats", float(self.rng.uniform(lo, hi)))

    def float_array(self, shape, lo, hi):
        return self._rec("float_array", self.rng.uniform(lo, hi, size=shape))

    def int_array(self, shape, lo, hi):
        return self._rec("int_array", self.rng.integers(lo, hi + 1, size=shape))

    def choice(self, options):
        return self._rec("choice", options[int(self.rng.integers(0, len(options)))])

    def bool(self):
        return self._rec("bool", bool(self.rng.integers(0, 2)))


def sweep(cases: int = 100, seed: int = 0):
    """Decorator: run `fn(draw)` for `cases` seeded random cases."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            for case in range(cases):
                rng = np.random.default_rng(seed * 100003 + case)
                draw = Draw(rng)
                try:
                    # works for both plain functions and methods (self first)
                    fn(*args, draw, **kwargs)
                except Exception as e:  # noqa: BLE001 - reraise with context
                    raise AssertionError(
                        f"property failed at case={case} (seed={seed}): "
                        f"drawn={draw.trace!r}\n{type(e).__name__}: {e}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
