"""Model-substrate invariants: decode==train consistency, ring caches,
rollback, blockwise attention oracle, MoE dispatch vs dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.models.attention import dot_attention
from repro.models.moe import apply_moe, apply_moe_reference, init_moe
from repro.serving.kv_cache import (init_attn_cache, rollback, write_chunk,
                                    write_prefill)
from tests.proptest import sweep

CONSISTENCY_ARCHS = ["olmo-1b", "qwen3-8b", "h2o-danube-3-4b", "xlstm-350m",
                     "recurrentgemma-9b", "deepseek-v2-lite-16b",
                     "stablelm-12b", "qwen3-moe-235b-a22b", "internvl2-2b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_train(arch):
    """prefill(6) + token-by-token decode == full train-mode forward."""
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    kwargs = {}
    if cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_prefix_embeds, cfg.d_model))
    ref = model.forward(params, toks, mode="train", **kwargs).logits
    p_off = cfg.num_prefix_embeds if cfg.frontend == "vision" else 0

    cache = model.init_cache(B, 40)
    pre = model.forward(params, toks[:, :6], mode="prefill", cache=cache,
                        **kwargs)
    outs = [pre.logits]
    cache = pre.cache
    for t in range(6, S):
        pos = jnp.full((B, 1), t + p_off, jnp.int32)
        st = model.forward(params, toks[:, t:t + 1], mode="decode",
                           cache=cache, positions=pos)
        cache = st.cache
        outs.append(st.logits)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 3e-3, f"{arch}: decode/train mismatch {err}"


def test_sliding_window_ring_beyond_window():
    """Decoding past the window: ring cache output == train-mode forward
    (the windowed mask makes both attend to the same last-w tokens)."""
    cfg = get_reduced("h2o-danube-3-4b", window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24  # 3x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    ref = model.forward(params, toks, mode="train").logits
    cache = model.init_cache(B, 8)   # ring sized to window
    pre = model.forward(params, toks[:, :4], mode="prefill", cache=cache)
    cache, outs = pre.cache, [pre.logits]
    for t in range(4, S):
        st = model.forward(params, toks[:, t:t + 1], mode="decode",
                           cache=cache,
                           positions=jnp.full((B, 1), t, jnp.int32))
        cache, _ = st.cache, outs.append(st.logits)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 3e-3, f"ring decode mismatch {err}"


def test_encdec_decode_consistency():
    """Whisper: decoder decode with cross-attention == train-mode."""
    cfg = get_reduced("whisper-base")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    audio = jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.encoder.source_len, cfg.d_model))
    enc = model.encode(params, audio)
    ref = model.forward(params, toks, mode="train", enc_out=enc).logits
    cache = model.init_cache(B, 24)
    pre = model.forward(params, toks[:, :5], mode="prefill", cache=cache,
                        enc_out=enc)
    cache, outs = pre.cache, [pre.logits]
    for t in range(5, S):
        st = model.forward(params, toks[:, t:t + 1], mode="decode",
                           cache=cache, enc_out=enc,
                           positions=jnp.full((B, 1), t, jnp.int32))
        cache = st.cache
        outs.append(st.logits)
    err = float(jnp.max(jnp.abs(jnp.concatenate(outs, 1) - ref)))
    assert err < 3e-3, err


class TestKVCache:
    def test_write_and_rollback(self):
        cache = init_attn_cache(2, 8, 1, 4, jnp.float32)
        k = jnp.ones((2, 3, 1, 4))
        cache = write_prefill(cache, (k, k * 2), jnp.asarray([3, 2]))
        np.testing.assert_array_equal(np.asarray(cache.next_pos), [3, 2])
        assert np.asarray(cache.pos_arr)[0, :3].tolist() == [0, 1, 2]
        assert np.asarray(cache.pos_arr)[1, 2] == -1
        # append a 2-token chunk with row 1 masked at step 2
        k2 = jnp.full((2, 2, 1, 4), 5.0)
        valid = jnp.asarray([[True, True], [True, False]])
        cache = write_chunk(cache, (k2, k2), valid)
        np.testing.assert_array_equal(np.asarray(cache.next_pos), [5, 3])
        # rollback row 0 to position 4
        cache = rollback(cache, jnp.asarray([4, 3]))
        pos = np.asarray(cache.pos_arr)
        assert pos[0].max() == 3 and np.asarray(cache.next_pos)[0] == 4

    def test_reset_and_prefill_rows(self):
        """Continuous-batching admission: one row re-prefills, neighbours
        keep their contents bit-exact."""
        from repro.serving.kv_cache import prefill_rows, reset_rows
        rng = np.random.default_rng(0)
        k0 = jnp.asarray(rng.normal(size=(2, 5, 1, 4)), jnp.float32)
        cache = write_prefill(init_attn_cache(2, 8, 1, 4, jnp.float32),
                              (k0, k0 * 2), jnp.asarray([5, 4]))
        rows = jnp.asarray([True, False])
        cleared = reset_rows(cache, rows)
        assert np.all(np.asarray(cleared.pos_arr)[0] == -1)
        assert np.asarray(cleared.next_pos).tolist() == [0, 4]
        np.testing.assert_array_equal(np.asarray(cleared.pos_arr)[1],
                                      np.asarray(cache.pos_arr)[1])
        # re-prefill row 0 with a 3-token prompt; row 1 must be untouched
        k1 = jnp.asarray(rng.normal(size=(2, 3, 1, 4)), jnp.float32)
        out = prefill_rows(cache, (k1, k1), jnp.asarray([3, 0]), rows)
        np.testing.assert_array_equal(np.asarray(out.pos_arr)[0],
                                      [0, 1, 2, -1, -1, -1, -1, -1])
        np.testing.assert_allclose(np.asarray(out.k)[0, :3],
                                   np.asarray(k1)[0], atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out.pos_arr)[1],
                                      np.asarray(cache.pos_arr)[1])
        np.testing.assert_allclose(np.asarray(out.k)[1],
                                   np.asarray(cache.k)[1], atol=1e-6)
        np.testing.assert_allclose(np.asarray(out.v)[1],
                                   np.asarray(cache.v)[1], atol=1e-6)
        assert np.asarray(out.next_pos).tolist() == [3, 4]

    @sweep(cases=15, seed=4)
    def test_ring_prefill_equals_chunked(self, draw):
        """Bulk ring prefill == writing the same tokens one by one."""
        l = draw.integers(3, 6)
        s = draw.integers(1, 10)
        b = 2
        k = jnp.asarray(np.random.default_rng(draw.integers(0, 99))
                        .normal(size=(b, s, 1, 2)), jnp.float32)
        lengths = jnp.asarray([s, max(1, s - 1)], jnp.int32)
        c1 = write_prefill(init_attn_cache(b, l, 1, 2, jnp.float32),
                           (k, k), lengths, ring=True)
        c2 = init_attn_cache(b, l, 1, 2, jnp.float32)
        valid = jnp.arange(s)[None, :] < lengths[:, None]
        c2 = write_chunk(c2, (k, k), valid, ring=True)
        np.testing.assert_array_equal(np.asarray(c1.pos_arr),
                                      np.asarray(c2.pos_arr))
        np.testing.assert_allclose(np.asarray(c1.k), np.asarray(c2.k),
                                   atol=1e-6)


class TestAttentionCore:
    @sweep(cases=15, seed=5)
    def test_blockwise_equals_naive(self, draw):
        """Online-softmax blockwise attention == naive softmax attention."""
        b = draw.integers(1, 3)
        sq = draw.integers(1, 6)
        l = draw.choice([4, 8, 16, 24])
        h, kv, hd = 4, draw.choice([1, 2, 4]), 8
        window = draw.choice([0, 0, 3, 7])
        rng = np.random.default_rng(draw.integers(0, 999))
        q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        q_pos = jnp.asarray(rng.integers(0, l, size=(b, sq)), jnp.int32)
        kv_pos = jnp.asarray(rng.integers(0, l, size=(b, l)), jnp.int32)
        kv_valid = jnp.asarray(rng.random((b, l)) > 0.2)
        out = dot_attention(q, k, v, q_pos, kv_pos, kv_valid, window=window,
                            block_size=4)
        # naive reference
        qf = q.reshape(b, sq, kv, h // kv, hd)
        s = jnp.einsum("bqkgh,blkh->bqkgl", qf, k) / np.sqrt(hd)
        mask = kv_valid[:, None, :] & (kv_pos[:, None, :] <= q_pos[:, :, None])
        if window:
            mask &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # rows with no valid kv: zero them like the blockwise code does
        any_valid = jnp.any(mask, axis=-1)[:, :, None, None, None]
        ref = jnp.einsum("bqkgl,blkh->bqkgh", p, v) * any_valid
        ref = ref.reshape(b, sq, h, hd)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


class TestMoE:
    @sweep(cases=10, seed=6)
    def test_dispatch_matches_dense_reference(self, draw):
        """Capacity dispatch == dense all-experts oracle when nothing drops."""
        from repro.configs import get_reduced
        cfg = get_reduced("qwen3-moe-235b-a22b")
        params = init_moe(jax.random.PRNGKey(draw.integers(0, 99)), cfg,
                          jnp.float32)
        x = jnp.asarray(np.random.default_rng(draw.integers(0, 99))
                        .normal(size=(2, 6, cfg.d_model)), jnp.float32)
        y, aux = apply_moe(params, x, cfg)
        ref = apply_moe_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=2e-5)
        assert float(aux) >= 0.0

    def test_router_loadbalance_loss_range(self):
        """Uniform routing minimizes the aux loss at weight * 1.0."""
        cfg = get_reduced("qwen3-moe-235b-a22b")
        params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, cfg.d_model)), jnp.float32)
        _, aux = apply_moe(params, x, cfg)
        w = cfg.moe.router_aux_weight
        assert float(aux) >= 0.9 * w  # >= the uniform lower bound E*(1/E)*1
