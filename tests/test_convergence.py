"""Convergence of GoodSpeed to the optimal goodput x* (paper Thm 1, Fig 4).

Validates the paper's own claims:
  * the fluid dynamics x' = v - x converge to the water-filling optimum x*;
  * the discrete round loop's smoothed goodput X^beta concentrates near x*
    and its utility surpasses Fixed-S and Random-S (Fig 4);
  * stabilization happens within the paper's reported ~400-600 rounds;
  * the estimator alpha_hat tracks the true (ergodic) acceptance rates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.core.fluid import integrate_fluid, optimal_goodput
from repro.core.goodput import expected_goodput
from repro.core.utility import UtilitySpec

ALPHAS = np.array([0.9, 0.75, 0.6, 0.45, 0.3, 0.85, 0.5, 0.7])
N = len(ALPHAS)
C = 20  # paper's 150-token config uses C in {16, 20}


@pytest.fixture(scope="module")
def xstar():
    s, x = optimal_goodput(jnp.asarray(ALPHAS, jnp.float32), C)
    return np.asarray(s), np.asarray(x)


class TestFluidOptimum:
    def test_waterfilling_budget(self, xstar):
        s, x = xstar
        np.testing.assert_allclose(s.sum(), C, rtol=1e-3)
        assert np.all(s >= -1e-6)
        np.testing.assert_allclose(
            x, np.asarray(expected_goodput(jnp.asarray(s), jnp.asarray(ALPHAS))),
            rtol=1e-5)

    def test_waterfilling_kkt(self, xstar):
        """KKT: a common price lambda lies in every interior client's
        subdifferential of log mu_bar.  mu_bar is piecewise linear, so at
        integer s the derivative jumps from a^k/mu to a^(k+1)/mu; interior
        clients' [right, left] derivative intervals must share a point."""
        s, x = xstar
        a = ALPHAS
        interior = (s > 0.05) & (s < C - 0.05)
        assert interior.sum() >= 2
        k = np.floor(s + 1e-6)
        frac = s - k
        at_break = frac < 1e-3
        left = np.where(at_break, a ** k / x, a ** (k + 1.0) / x)
        right = a ** (k + 1.0) / x
        lo = right[interior].max()
        hi = left[interior].min()
        assert lo <= hi * 1.1, (lo, hi, s, x)

    def test_fluid_ode_converges_to_xstar(self, xstar):
        _, x_opt = xstar
        x0 = jnp.full((N,), 1.0)
        traj = integrate_fluid(jnp.asarray(ALPHAS, jnp.float32), C, x0,
                               steps=600, dt=0.05)
        final = np.asarray(traj[-1])
        np.testing.assert_allclose(final, x_opt, rtol=0.08)

    def test_fluid_utility_monotone_tail(self, xstar):
        """U(x(t)) increases along the fluid trajectory (Lyapunov property)."""
        u = UtilitySpec(alpha=1.0)
        traj = integrate_fluid(jnp.asarray(ALPHAS, jnp.float32), C,
                               jnp.full((N,), 0.5), steps=400, dt=0.05)
        vals = np.asarray(jax.vmap(u.value)(traj))
        # beyond the transient, non-decreasing up to tiny numerical wiggle
        tail = vals[50:]
        assert np.all(np.diff(tail) > -1e-3)


def _run_policy(policy, rounds=800, seed=0, alphas=ALPHAS):
    coord = Coordinator(
        n=N, C=C, policy=policy,
        estimator=GoodputEstimator(eta=StepSchedule(0.3), beta=StepSchedule(0.05)),
    )
    traj = jnp.tile(jnp.asarray(alphas, jnp.float32), (rounds, 1))
    _, logs = coord.simulate_analytic(jax.random.PRNGKey(seed), traj)
    return logs


class TestDiscreteConvergence:
    def test_goodspeed_reaches_xstar(self, xstar):
        _, x_opt = xstar
        logs = _run_policy("goodspeed")
        xb = np.asarray(logs.goodput_est[-1])
        # smoothed goodput concentrates near the fluid optimum
        np.testing.assert_allclose(xb, x_opt, rtol=0.15)

    def test_utility_beats_baselines(self, xstar):
        """Fig 4: GoodSpeed utility > Fixed-S, Random-S at convergence, and
        close to U(x*)."""
        u = UtilitySpec(alpha=1.0)
        _, x_opt = xstar
        u_star = float(u.value(jnp.asarray(x_opt)))
        tail = slice(-200, None)
        utils = {}
        for pol in ("goodspeed", "fixed", "random"):
            logs = _run_policy(pol)
            # utility of empirical average goodput, as in Fig 4
            avg = np.asarray(jnp.mean(logs.realized[tail], axis=0))
            utils[pol] = float(u.value(jnp.asarray(avg)))
        assert utils["goodspeed"] >= utils["fixed"] - 1e-3, utils
        assert utils["goodspeed"] >= utils["random"] + 1e-3, utils
        assert utils["goodspeed"] >= u_star - 0.35, (utils, u_star)

    def test_stabilizes_within_600_rounds(self):
        """Paper Fig 4: running-average utility stabilizes by ~iteration 600."""
        u = UtilitySpec(alpha=1.0)
        logs = _run_policy("goodspeed", rounds=900)
        realized = np.asarray(logs.realized)  # [T, N]
        csum = np.cumsum(realized, axis=0)
        denom = np.arange(1, realized.shape[0] + 1)[:, None]
        running = csum / denom
        uvals = np.array([float(u.value(jnp.asarray(r))) for r in running[::30]])
        late = uvals[600 // 30:]
        assert np.max(late) - np.min(late) < 0.25, late

    def test_alpha_estimator_tracks_truth(self):
        logs = _run_policy("goodspeed", rounds=600)
        ah = np.asarray(logs.alpha_hat[-1])
        np.testing.assert_allclose(ah, ALPHAS, atol=0.08)

    def test_fairness_no_starvation(self):
        """Log utility never starves a low-alpha client (Lemma 2 boundary
        drift): every client's long-run goodput stays >= 1 (the correction
        token) and the allocation visits every client."""
        logs = _run_policy("goodspeed", rounds=500)
        xb = np.asarray(logs.goodput_est[-1])
        assert np.all(xb >= 0.9)
        total_slots = np.asarray(logs.S).sum(axis=0)
        assert np.all(total_slots > 0)

    def test_nonstationary_tracking(self):
        """Alpha shift mid-run (paper's dynamic prompts): estimator re-tracks
        and goodput re-converges toward the new optimum."""
        rounds = 1200
        a1 = np.tile(ALPHAS, (rounds // 2, 1))
        shifted = np.roll(ALPHAS, 3)
        a2 = np.tile(shifted, (rounds // 2, 1))
        traj = jnp.asarray(np.concatenate([a1, a2]), jnp.float32)
        coord = Coordinator(
            n=N, C=C, policy="goodspeed",
            estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                       beta=StepSchedule(0.05)))
        _, logs = coord.simulate_analytic(jax.random.PRNGKey(1), traj)
        ah = np.asarray(logs.alpha_hat[-1])
        np.testing.assert_allclose(ah, shifted, atol=0.1)
        _, x_opt2 = optimal_goodput(jnp.asarray(shifted, jnp.float32), C)
        np.testing.assert_allclose(np.asarray(logs.goodput_est[-1]),
                                   np.asarray(x_opt2), rtol=0.2)


class TestEstimatorUnit:
    def test_ema_fixed_point(self):
        est = GoodputEstimator(eta=StepSchedule(0.5), beta=StepSchedule(0.5))
        st = est.init(3)
        for _ in range(200):
            st = est.update(st, jnp.asarray([4.0, 2.0, 1.0]),
                            jnp.asarray([5, 5, 5]), jnp.asarray([3.0, 2.0, 1.5]))
        np.testing.assert_allclose(np.asarray(st.alpha_hat),
                                   [0.8, 0.4, 0.2], atol=1e-4)
        np.testing.assert_allclose(np.asarray(st.goodput),
                                   [3.0, 2.0, 1.5], atol=1e-4)

    def test_zero_S_holds_alpha(self):
        est = GoodputEstimator()
        st = est.init(2)
        a0 = np.asarray(st.alpha_hat)
        st2 = est.update(st, jnp.asarray([0.0, 3.0]), jnp.asarray([0, 4]),
                         jnp.asarray([1.0, 4.0]))
        assert float(st2.alpha_hat[0]) == pytest.approx(float(a0[0]))
        assert float(st2.alpha_hat[1]) != pytest.approx(float(a0[1]))

    def test_decaying_schedule(self):
        s = StepSchedule(0.5, exponent=0.6)
        assert float(s(0)) == pytest.approx(0.5)
        assert float(s(100)) < 0.05
