"""shard_map expert-parallel MoE (§Perf it.1e): numeric equivalence with the
GSPMD dispatch path on a multi-device host mesh (subprocess: forced device
count must precede jax init)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.distributed.sharding import TRAIN_RULES, use_sharding
from repro.launch.mesh import make_host_mesh
from repro.models.moe import apply_moe, init_moe

for arch in ("qwen3-moe-235b-a22b", "deepseek-v2-lite-16b"):
    cfg = get_reduced(arch)   # 4 experts, top-2, lossless capacity
    mesh = make_host_mesh(2, 4)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 8, cfg.d_model)), jnp.float32)
    y_ref, _ = apply_moe(params, x, cfg)
    cfg_ep = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, shard_map_ep=True))
    with mesh, use_sharding(mesh, TRAIN_RULES):
        y_ep, aux = jax.jit(lambda p, xx: apply_moe(p, xx, cfg_ep))(params, x)
    err = float(jnp.max(jnp.abs(y_ep - y_ref)))
    assert err < 1e-5, (arch, err)
    assert float(aux) > 0
    # gradient path works through the all-to-alls
    def loss(p):
        y, a = apply_moe(p, x, cfg_ep)
        return jnp.sum(y ** 2) + a
    with mesh, use_sharding(mesh, TRAIN_RULES):
        g = jax.jit(jax.grad(loss))(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print(f"{arch}: EP equivalence OK err={err:.2e} gradnorm={gn:.2f}")
"""


@pytest.mark.slow
def test_moe_expert_parallel_equivalence(tmp_path):
    script = tmp_path / "moe_ep.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.count("EP equivalence OK") == 2
