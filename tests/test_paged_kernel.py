"""Block-table-native paged decode kernel vs the gather-path oracle, and
end-to-end ``attn_backend`` equivalence.

The oracle is ``attention.paged_dot_attention`` (paged_view gather + dense
core).  Cache states under test are produced by driving the REAL paged
primitives — prefill, masked chunk writes, rollback, row retirement — so
the block tables carry holes, freed-and-reclaimed blocks, wrapped
allocation order, and fully-idle rows (``pos_arr == -1``), exactly the
states the serving engine produces.  See docs/KV_CACHE.md for the kernel
contract (safe-index rule for unbacked slots).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.configs import get_reduced
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode import paged_flash_decode
from repro.models import Model
from repro.models.attention import dot_attention, paged_dot_attention
from repro.serving import kv_cache as kc
from repro.serving.engine import GoodSpeedEngine
from tests.proptest import sweep


def _random_paged_cache(rng, b, length, kv, hd, bs, num_blocks=0):
    """Drive real primitives to a state with holes, reuse, and idle rows."""
    cache = kc.init_paged_attn_cache(b, length, kv, hd, jnp.float32, bs,
                                     num_blocks=num_blocks)
    mk = lambda s: (jnp.asarray(rng.normal(size=(b, s, kv, hd)),
                                jnp.float32),
                    jnp.asarray(rng.normal(size=(b, s, kv, hd)),
                                jnp.float32))
    lengths = jnp.asarray(rng.integers(1, length // 2, size=(b,)), jnp.int32)
    cache = kc.write_prefill(cache, mk(int(lengths.max())), lengths)
    for _ in range(rng.integers(0, 3)):
        s = int(rng.integers(1, 6))
        valid = jnp.asarray(rng.random((b, s)) < 0.8)
        cache = kc.write_chunk(cache, mk(s), valid)
        if rng.random() < 0.5:   # speculative rollback: tail blocks freed
            keep = jnp.maximum(cache.next_pos
                               - jnp.asarray(rng.integers(0, 4, size=(b,)),
                                             jnp.int32), 0)
            cache = kc.rollback(cache, keep)
    if rng.random() < 0.5:       # retire a row -> fully-idle slots
        rows = jnp.asarray(rng.random((b,)) < 0.5)
        cache = kc.reset_rows(cache, rows)
    return cache


class TestPagedFlashDecode:
    @sweep(cases=20, seed=30)
    def test_matches_gather_oracle(self, draw):
        """Kernel and fused ref match paged_dot_attention on random
        admit/rollback/retire cache states, chunk and single-token."""
        rng = np.random.default_rng(draw.integers(0, 99999))
        b = draw.integers(1, 4)
        kv = draw.choice([1, 2])
        g = draw.choice([1, 2, 4])
        h = kv * g
        hd = draw.choice([16, 32])
        bs = draw.choice([4, 8])
        length = draw.choice([32, 48, 64])
        sq = draw.choice([1, 3, 5])
        cache = _random_paged_cache(rng, b, length, kv, hd, bs)
        q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
        q_pos = cache.next_pos[:, None] + jnp.arange(sq)[None, :]
        ref = paged_dot_attention(q, cache, q_pos)
        for impl in ("ref", "kernel"):
            out = paged_flash_decode(q, cache, q_pos, impl=impl)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=2e-5, rtol=2e-5)

    def test_idle_rows_exact_zero(self):
        """A fully-idle row (all slots pos_arr = -1) yields exact zeros —
        never a mean-of-v — matching the jnp core's masked-zero rule."""
        rng = np.random.default_rng(1)
        b, kv, hd, bs, length = 2, 2, 16, 8, 32
        cache = kc.init_paged_attn_cache(b, length, kv, hd, jnp.float32, bs)
        vals = (jnp.asarray(rng.normal(size=(b, 6, kv, hd)), jnp.float32),
                jnp.asarray(rng.normal(size=(b, 6, kv, hd)), jnp.float32))
        cache = kc.write_prefill(cache, vals,
                                 jnp.asarray([6, 0], jnp.int32))
        q = jnp.asarray(rng.normal(size=(b, 2, 4, hd)), jnp.float32)
        q_pos = jnp.asarray([[6, 7], [0, 1]], jnp.int32)
        for impl in ("ref", "kernel"):
            out = np.asarray(paged_flash_decode(q, cache, q_pos, impl=impl))
            assert np.all(out[1] == 0.0), impl
            assert np.any(out[0] != 0.0), impl

    def test_unbacked_slots_never_leak_block_zero(self):
        """Safe-index rule: a -1 table entry clamps to pool block 0, whose
        (other request's) K/V must be masked out, not attended."""
        rng = np.random.default_rng(2)
        kv, hd, bs, length = 1, 16, 4, 16
        cache = kc.init_paged_attn_cache(2, length, kv, hd, jnp.float32, bs)
        vals = (jnp.asarray(rng.normal(size=(2, 4, kv, hd)), jnp.float32),
                jnp.asarray(rng.normal(size=(2, 4, kv, hd)), jnp.float32))
        # row 0 owns block 0 entirely; row 1 holds ONE token in block 1
        cache = kc.write_prefill(cache, vals, jnp.asarray([4, 1], jnp.int32))
        q = jnp.asarray(rng.normal(size=(2, 1, kv, hd)), jnp.float32)
        q_pos = jnp.asarray([[4], [1]], jnp.int32)
        # row 1's single valid slot -> output must be exactly its value
        expect = np.asarray(cache.vpool[int(cache.table[1, 0]), 0, 0])
        for impl in ("ref", "kernel"):
            out = np.asarray(paged_flash_decode(q, cache, q_pos, impl=impl))
            np.testing.assert_allclose(out[1, 0, 0], expect, atol=1e-5)

    def test_mla_cache_rejected(self):
        cache = kc.init_paged_mla_cache(1, 16, 4, 2, jnp.float32, 8)
        q = jnp.zeros((1, 1, 2, 4))
        with pytest.raises(TypeError):
            paged_flash_decode(q, cache, jnp.zeros((1, 1), jnp.int32))


class TestChunkedFlashDecode:
    """The extended decode_attention ops: chunk queries, ring caches."""

    @sweep(cases=15, seed=31)
    def test_chunk_matches_dot_attention(self, draw):
        rng = np.random.default_rng(draw.integers(0, 99999))
        b = draw.integers(1, 3)
        kv = draw.choice([1, 2])
        g = draw.choice([1, 2])
        h = kv * g
        hd = draw.choice([16, 32])
        l = draw.choice([24, 40])
        sq = draw.choice([1, 4, 6])
        window = draw.choice([0, 0, 8])
        fill = rng.integers(1, l + 1, size=(b,))
        kv_pos = np.full((b, l), -1, np.int32)
        for i in range(b):
            kv_pos[i, :fill[i]] = np.arange(fill[i])
        kv_pos = jnp.asarray(kv_pos)
        q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        q_pos = jnp.asarray(fill - 1, jnp.int32)[:, None] \
            + jnp.arange(sq)[None, :]
        ref = dot_attention(q, k, v, q_pos, kv_pos, kv_pos >= 0,
                            window=window)
        for impl, tol in (("kernel", 3e-5), ("ref", 0.0)):
            out = flash_decode(q, k, v, kv_pos, q_pos, window=window,
                               impl=impl, tile=8)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=tol, rtol=tol)

    def test_ring_cache_dispatch(self):
        """Cache-form dispatch accepts a ring AttnCache (wrapped pos_arr)
        and matches the jnp core's window masking."""
        rng = np.random.default_rng(5)
        b, kv, hd, l, window = 1, 2, 16, 8, 6
        cache = kc.init_attn_cache(b, l, kv, hd, jnp.float32)
        vals = (jnp.asarray(rng.normal(size=(b, 12, kv, hd)), jnp.float32),
                jnp.asarray(rng.normal(size=(b, 12, kv, hd)), jnp.float32))
        cache = kc.write_prefill(cache, vals,
                                 jnp.asarray([12], jnp.int32), ring=True)
        assert int(cache.pos_arr.min()) >= 0  # wrapped, fully occupied
        q = jnp.asarray(rng.normal(size=(b, 2, 4, hd)), jnp.float32)
        q_pos = jnp.asarray([[11, 12]], jnp.int32)
        ref = dot_attention(q, cache.k, cache.v, q_pos, cache.pos_arr,
                            cache.pos_arr >= 0, window=window)
        for impl in ("kernel", "ref"):
            out = flash_decode(q, cache, q_pos=q_pos, window=window,
                               impl=impl, tile=4)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=3e-5, rtol=3e-5)

    def test_mla_cache_rejected(self):
        cache = kc.init_mla_cache(1, 8, 4, 2, jnp.float32)
        with pytest.raises(TypeError):
            flash_decode(jnp.zeros((1, 2, 4)), cache,
                         q_pos=jnp.zeros((1,), jnp.int32))


class TestBackendEquivalence:
    """ACCEPTANCE: attn_backend="kernel" and "jnp" emit identical
    accepted-token sequences on a mixed admit/retire/EOS serve_requests
    trace, for both paged and static caches (mirrors
    tests/test_paged_cache.py's paged-vs-static equivalence rule).
    The trace harness lives in conftest.py (``mixed_trace``) and is shared
    with the placement-policy equivalence suite."""
    VOCAB = conftest.MIXED_TRACE_VOCAB

    @pytest.fixture(scope="class")
    def pair(self, serve_pair):
        return serve_pair

    @pytest.mark.parametrize("paged", [False, True])
    def test_identical_accepted_tokens(self, mixed_trace, paged):
        seqs = {backend: conftest.generated_seqs(
                    mixed_trace(paged_kv=paged, attn_backend=backend))
                for backend in ("jnp", "kernel")}
        assert seqs["kernel"] == seqs["jnp"]

    def test_ring_and_mla_stacks_degrade_cleanly(self):
        """Sliding-window (ring) draft + MLA target under the kernel
        backend: ring decode dispatches to flash_decode, MLA stays on the
        absorbed jnp path — no crash, identical emissions."""
        dm = Model(get_reduced("h2o-danube-3-4b", num_layers=2, d_model=64,
                               num_heads=2, num_kv_heads=2, head_dim=32,
                               d_ff=128, vocab_size=self.VOCAB))
        tm = Model(get_reduced("deepseek-v2-lite-16b", num_layers=2,
                               d_model=64, num_heads=2, num_kv_heads=2,
                               d_ff=128, vocab_size=self.VOCAB))
        assert set(dm.cfg.layer_kinds) == {"sliding_attn"}
        assert tm.cfg.mla is not None
        dp, tp = dm.init(jax.random.PRNGKey(0)), tm.init(jax.random.PRNGKey(1))
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, self.VOCAB, size=9).astype(np.int32)
                   for _ in range(2)]
        hists = {}
        for backend in ("jnp", "kernel"):
            eng = GoodSpeedEngine(draft_model=dm, target_model=tm,
                                  n_servers=2, C=6, s_max=3, cache_len=64,
                                  attn_backend=backend)
            hists[backend] = eng.serve(jax.random.PRNGKey(4), prompts,
                                       dp, tp, rounds=4)
        for h0, h1 in zip(hists["jnp"], hists["kernel"]):
            np.testing.assert_array_equal(h0.emitted, h1.emitted)

    def test_backend_threads_through_engine(self, pair):
        """The engine flag rebuilds both models' configs; None inherits."""
        dm, tm, dp, tp = pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                              C=8, s_max=4, cache_len=64,
                              attn_backend="kernel")
        assert eng.draft_model.cfg.attn_backend == "kernel"
        assert eng.target_model.cfg.attn_backend == "kernel"
        inherit = GoodSpeedEngine(draft_model=eng.draft_model,
                                  target_model=eng.target_model,
                                  n_servers=2, C=8, s_max=4, cache_len=64)
        assert inherit.attn_backend == "kernel"
        with pytest.raises(ValueError, match="attn_backend"):
            GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                            C=8, s_max=4, attn_backend="cuda")
