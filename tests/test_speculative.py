"""Speculative verification: losslessness, acceptance statistics, ragged batching."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.goodput import expected_goodput
from repro.core.speculative import acceptance_probability, verify
from tests.proptest import sweep


def _random_logit_pair(rng, v, sharp_q=1.0, sharp_p=1.0):
    q = rng.normal(size=(v,)) * sharp_q
    p = rng.normal(size=(v,)) * sharp_p
    return p, q


def _make_batch(rng, n, s_max, v, couple=0.0):
    """Random draft/target logits; `couple` in [0,1] interpolates q toward p
    (couple=1 -> q==p -> alpha=1)."""
    p = rng.normal(size=(n, s_max + 1, v)).astype(np.float32)
    q_ind = rng.normal(size=(n, s_max, v)).astype(np.float32)
    q = couple * p[:, :s_max, :] + (1.0 - couple) * q_ind
    return jnp.asarray(q), jnp.asarray(p)


class TestVerifyMechanics:
    @sweep(cases=20, seed=10)
    def test_shapes_and_feasibility(self, draw):
        n = draw.integers(1, 6)
        s_max = draw.integers(1, 8)
        v = draw.integers(3, 40)
        rng = np.random.default_rng(draw.integers(0, 10_000))
        q, p = _make_batch(rng, n, s_max, v)
        lengths = jnp.asarray(rng.integers(0, s_max + 1, size=(n,)), jnp.int32)
        toks = jnp.asarray(rng.integers(0, v, size=(n, s_max)), jnp.int32)
        res = verify(jax.random.PRNGKey(draw.integers(0, 99)), toks, q, p, lengths)
        assert res.accepted.shape == (n,)
        assert bool(jnp.all(res.accepted <= lengths))
        assert bool(jnp.all(res.accepted >= 0))
        assert bool(jnp.all(res.num_emitted == res.accepted + 1))
        assert bool(jnp.all((res.extra_token >= 0) & (res.extra_token < v)))
        # emitted row i: first m_i tokens match the draft, position m_i is extra
        em = np.asarray(res.emitted)
        m = np.asarray(res.accepted)
        for i in range(n):
            np.testing.assert_array_equal(em[i, :m[i]], np.asarray(toks)[i, :m[i]])
            assert em[i, m[i]] == int(res.extra_token[i])
            assert np.all(em[i, m[i] + 1:] == -1)
        # Eq.3 indicator sums are within [0, S_i]
        rs = np.asarray(res.accept_ratio_sum)
        assert np.all(rs >= -1e-6) and np.all(rs <= np.asarray(lengths) + 1e-5)

    def test_identical_models_accept_everything(self):
        """q == p => ratio = 1 => all drafts accepted, extra from bonus row."""
        rng = np.random.default_rng(0)
        n, s_max, v = 4, 6, 50
        p = jnp.asarray(rng.normal(size=(n, s_max + 1, v)), jnp.float32)
        q = p[:, :s_max, :]
        toks = jnp.asarray(rng.integers(0, v, size=(n, s_max)), jnp.int32)
        lengths = jnp.full((n,), s_max, jnp.int32)
        res = verify(jax.random.PRNGKey(1), toks, q, p, lengths)
        assert bool(jnp.all(res.accepted == s_max))
        np.testing.assert_allclose(np.asarray(res.accept_ratio_sum),
                                   np.full(n, s_max), rtol=1e-5)

    def test_disjoint_support_rejects_first(self):
        """q puts all mass on token 0, p on token 1 => reject at position 0
        and the correction is forced to token 1."""
        n, s_max, v = 2, 4, 8
        q = jnp.full((n, s_max, v), -30.0).at[:, :, 0].set(30.0)
        p = jnp.full((n, s_max + 1, v), -30.0).at[:, :, 1].set(30.0)
        toks = jnp.zeros((n, s_max), jnp.int32)
        res = verify(jax.random.PRNGKey(2), toks, q, p,
                     jnp.full((n,), s_max, jnp.int32))
        assert bool(jnp.all(res.accepted == 0))
        assert bool(jnp.all(res.extra_token == 1))

    def test_zero_length_rows(self):
        """S_i = 0 rows emit exactly one token sampled from p row 0."""
        rng = np.random.default_rng(3)
        n, s_max, v = 3, 5, 16
        q, p = _make_batch(rng, n, s_max, v)
        toks = jnp.asarray(rng.integers(0, v, size=(n, s_max)), jnp.int32)
        lengths = jnp.zeros((n,), jnp.int32)
        res = verify(jax.random.PRNGKey(4), toks, q, p, lengths)
        assert bool(jnp.all(res.accepted == 0))
        assert bool(jnp.all(res.num_emitted == 1))
        assert float(jnp.sum(res.accept_ratio_sum)) == 0.0


class TestLosslessness:
    def test_acceptance_rate_matches_analytic(self):
        """Empirical acceptance fraction at position 0 == 1 - TV(p, q)."""
        rng = np.random.default_rng(7)
        v, trials = 24, 4000
        q1, p1 = _make_batch(rng, 1, 1, v, couple=0.5)
        alpha = float(acceptance_probability(p1[0, 0], q1[0, 0]))
        q = jnp.tile(q1, (trials, 1, 1))
        p = jnp.tile(p1, (trials, 1, 1))
        keys = jax.random.split(jax.random.PRNGKey(8), trials)
        toks = jax.vmap(lambda k: jax.random.categorical(k, q1[0, 0]))(keys)
        res = verify(jax.random.PRNGKey(9), toks[:, None].astype(jnp.int32),
                     q, p, jnp.ones((trials,), jnp.int32))
        emp = float(jnp.mean(res.accepted == 1))
        assert abs(emp - alpha) < 0.03, (emp, alpha)

    def test_output_distribution_matches_target(self):
        """The FIRST emitted token must be distributed exactly as the target
        model's p_0 — the defining losslessness property of speculative
        sampling.  Statistical check over many trials."""
        rng = np.random.default_rng(11)
        v, trials = 12, 6000
        q1, p1 = _make_batch(rng, 1, 1, v, couple=0.3)
        p0 = np.asarray(jax.nn.softmax(p1[0, 0]))
        q = jnp.tile(q1, (trials, 1, 1))
        p = jnp.tile(p1, (trials, 1, 1))
        kd, kv = jax.random.split(jax.random.PRNGKey(12))
        toks = jax.random.categorical(kd, jnp.tile(q1[0, 0], (trials, 1)),
                                      axis=-1)[:, None].astype(jnp.int32)
        res = verify(kv, toks, q, p, jnp.ones((trials,), jnp.int32))
        first = np.asarray(res.emitted[:, 0])
        counts = np.bincount(first, minlength=v) / trials
        # chi-square-ish bound: each bin within 4 sigma
        sigma = np.sqrt(p0 * (1 - p0) / trials)
        assert np.all(np.abs(counts - p0) < 4.5 * sigma + 5e-3), \
            np.max(np.abs(counts - p0) / (sigma + 1e-9))

    def test_expected_goodput_formula(self):
        """E[num_emitted] == (1 - a^(S+1)) / (1 - a) for iid acceptance."""
        rng = np.random.default_rng(13)
        v, s_max, trials = 16, 6, 3000
        q1, p1 = _make_batch(rng, 1, s_max, v, couple=0.6)
        # same (p,q) at every position -> iid acceptance with analytic alpha
        q1 = jnp.tile(q1[:, :1, :], (1, s_max, 1))
        p1 = jnp.tile(p1[:, :1, :], (1, s_max + 1, 1))
        alpha = float(acceptance_probability(p1[0, 0], q1[0, 0]))
        kd, kv = jax.random.split(jax.random.PRNGKey(14))
        toks = jax.random.categorical(
            kd, jnp.tile(q1[0, 0], (trials, s_max, 1)), axis=-1).astype(jnp.int32)
        res = verify(kv, toks, jnp.tile(q1, (trials, 1, 1)),
                     jnp.tile(p1, (trials, 1, 1)),
                     jnp.full((trials,), s_max, jnp.int32))
        expected = float(expected_goodput(jnp.asarray(float(s_max)),
                                          jnp.asarray(alpha)))
        emp = float(jnp.mean(res.num_emitted))
        assert abs(emp - expected) / expected < 0.05, (emp, expected, alpha)
