"""GOODSPEED-SCHED solver tests: exactness, feasibility, fairness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.goodput import expected_goodput, marginal_gain
from repro.core.scheduler import (fixed_s, objective_value, random_s,
                                  solve_greedy, solve_threshold)
from repro.core.utility import UtilitySpec
from tests.proptest import sweep


def brute_force(alpha, weights, C):
    """Exact greedy in numpy (provably optimal for separable concave)."""
    n = len(alpha)
    S = np.zeros(n, dtype=np.int64)
    for _ in range(C):
        g = weights * alpha ** (S + 1.0)
        S[np.argmax(g)] += 1
    return S


class TestSolverExactness:
    @sweep(cases=25, seed=1)
    def test_greedy_matches_numpy_objective(self, draw):
        n = draw.integers(1, 12)
        C = draw.integers(1, 48)
        alpha = draw.float_array((n,), 0.02, 0.98)
        w = draw.float_array((n,), 0.05, 5.0)
        S_np = brute_force(alpha, w, C)
        out = solve_greedy(jnp.asarray(alpha), jnp.asarray(w), C)
        obj_np = float(np.sum(w * np.asarray(
            expected_goodput(jnp.asarray(S_np, jnp.float32), jnp.asarray(alpha)))))
        assert int(jnp.sum(out.S)) == C
        np.testing.assert_allclose(float(out.objective), obj_np, rtol=1e-5)

    @sweep(cases=25, seed=2)
    def test_threshold_matches_greedy(self, draw):
        n = draw.integers(1, 16)
        C = draw.integers(1, 64)
        alpha = jnp.asarray(draw.float_array((n,), 0.02, 0.98))
        w = jnp.asarray(draw.float_array((n,), 0.05, 5.0))
        g = solve_greedy(alpha, w, C)
        t = solve_threshold(alpha, w, C)
        # allocations can differ at exact ties; objectives must match
        np.testing.assert_allclose(float(t.objective), float(g.objective),
                                   rtol=1e-5, atol=1e-6)
        assert int(jnp.sum(t.S)) == C

    @sweep(cases=20, seed=3)
    def test_s_max_cap_respected(self, draw):
        n = draw.integers(2, 10)
        C = draw.integers(4, 64)
        alpha = jnp.asarray(draw.float_array((n,), 0.1, 0.95))
        w = jnp.ones((n,))
        cap = jnp.asarray(draw.int_array((n,), 0, 6), jnp.int32)
        t = solve_threshold(alpha, w, C, s_max=cap)
        assert bool(jnp.all(t.S <= cap))
        assert int(jnp.sum(t.S)) <= C
        # budget is saturated unless every client is capped
        if int(jnp.sum(cap)) >= C:
            assert int(jnp.sum(t.S)) == C

    def test_optimality_vs_exhaustive_small(self):
        """Exhaustive enumeration on a tiny instance certifies optimality."""
        import itertools
        alpha = np.array([0.9, 0.5, 0.2])
        w = np.array([1.0, 2.0, 3.0])
        C = 6
        best = -1.0
        for S in itertools.product(range(C + 1), repeat=3):
            if sum(S) <= C:
                obj = float(np.sum(w * np.asarray(expected_goodput(
                    jnp.asarray(S, jnp.float32), jnp.asarray(alpha)))))
                best = max(best, obj)
        out = solve_threshold(jnp.asarray(alpha), jnp.asarray(w), C)
        np.testing.assert_allclose(float(out.objective), best, rtol=1e-6)


class TestSchedulerBehaviour:
    def test_high_alpha_gets_more_slots(self):
        alpha = jnp.asarray([0.95, 0.5, 0.1])
        w = jnp.ones((3,))
        S = solve_threshold(alpha, w, 24).S
        assert S[0] > S[1] > S[2]

    def test_log_utility_weights_prioritize_starved(self):
        """With 1/x weights, a starved client wins slots despite lower alpha."""
        alpha = jnp.asarray([0.6, 0.6])
        x = jnp.asarray([10.0, 0.5])  # client 1 starved
        w = UtilitySpec(alpha=1.0).grad(x)
        S = solve_threshold(alpha, w, 10).S
        assert S[1] > S[0]

    def test_fixed_and_random_budget(self):
        # fixed_s must spend the WHOLE budget: C % n used to be silently
        # dropped (C=20, n=8 allocated only 16 of 20 slots)
        S = np.asarray(fixed_s(8, 20))
        assert S.sum() == 20
        np.testing.assert_array_equal(S, [3, 3, 3, 3, 2, 2, 2, 2])
        Sr = random_s(jax.random.PRNGKey(0), 8, 20)
        assert int(jnp.sum(Sr)) == 20
        assert bool(jnp.all(Sr >= 0))

    @sweep(cases=20, seed=9)
    def test_fixed_s_spends_exact_budget(self, draw):
        n = draw.integers(1, 16)
        C = draw.integers(1, 64)
        S = np.asarray(fixed_s(n, C))
        assert S.sum() == C, (n, C, S)
        # deterministic remainder: first C % n servers get one extra
        assert np.all(S[:C % n] == C // n + 1) \
            and np.all(S[C % n:] == C // n), (n, C, S)

    def test_marginal_gain_is_decreasing(self):
        a = jnp.asarray([0.7])
        gains = [float(marginal_gain(jnp.asarray([s], jnp.float32), a)[0])
                 for s in range(10)]
        assert all(g1 > g2 for g1, g2 in zip(gains, gains[1:]))

    def test_degenerate_single_client(self):
        out = solve_threshold(jnp.asarray([0.8]), jnp.asarray([1.0]), 16)
        assert int(out.S[0]) == 16

    def test_extreme_alphas_do_not_nan(self):
        out = solve_threshold(jnp.asarray([1e-9, 1.0 - 1e-9]),
                              jnp.asarray([1.0, 1.0]), 8)
        assert np.isfinite(float(out.objective))
        assert int(jnp.sum(out.S)) == 8


class TestZeroCapRows:
    """Idle draft servers (remaining cap 0) must get S_i = 0 INSIDE the
    solver, with their share of the budget flowing to live servers —
    completion-aware scheduling for the request-lifecycle serve loop."""

    def test_threshold_and_greedy_zero_caps(self):
        alpha = jnp.asarray([0.9, 0.8, 0.7, 0.6])
        w = jnp.ones((4,))
        cap = jnp.asarray([0, 6, 0, 6], jnp.int32)
        for solver in (solve_threshold, solve_greedy):
            out = solver(alpha, w, 10, s_max=cap)
            S = np.asarray(out.S)
            assert S[0] == 0 and S[2] == 0, S
            # the idle budget lands on the live rows (caps allow 12 >= 10)
            assert S.sum() == 10, S

    def test_all_rows_idle(self):
        alpha = jnp.asarray([0.5, 0.5])
        w = jnp.ones((2,))
        cap = jnp.zeros((2,), jnp.int32)
        for solver in (solve_threshold, solve_greedy):
            out = solver(alpha, w, 8, s_max=cap)
            assert int(jnp.sum(out.S)) == 0

    @sweep(cases=15, seed=7)
    def test_random_idle_patterns(self, draw):
        n = draw.integers(2, 10)
        C = draw.integers(2, 40)
        alpha = jnp.asarray(draw.float_array((n,), 0.05, 0.95))
        w = jnp.asarray(draw.float_array((n,), 0.1, 4.0))
        cap = jnp.asarray(draw.int_array((n,), 0, 8), jnp.int32)
        out = solve_threshold(alpha, w, C, s_max=cap)
        S = np.asarray(out.S)
        assert np.all(S[np.asarray(cap) == 0] == 0)
        assert np.all(S <= np.asarray(cap))
        assert S.sum() == min(C, int(np.asarray(cap).sum()))

    def test_make_scheduler_routes_and_caps(self):
        from repro.core.scheduler import make_scheduler
        alpha = jnp.asarray([0.8, 0.6, 0.4])
        w = jnp.ones((3,))
        cap = jnp.asarray([0, 5, 5], jnp.int32)
        key = jax.random.PRNGKey(0)
        for name in ("goodspeed", "greedy", "fixed", "random"):
            S = np.asarray(make_scheduler(name)(alpha, w, 6, key=key,
                                                s_max=cap))
            assert S[0] == 0, (name, S)
            assert S.sum() <= 6 and np.all(S <= np.asarray(cap)), (name, S)
        with pytest.raises(ValueError):
            make_scheduler("nope")
