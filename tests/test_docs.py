"""Tier-1 wiring for the docs consistency check: README/docs code
references must name modules, attributes and files that actually exist
(``python -m scripts.check_docs`` is the standalone entry point)."""
from scripts.check_docs import _doc_files, collect_errors


def test_docs_exist():
    names = {p.name for p in _doc_files()}
    assert "README.md" in names
    assert "ARCHITECTURE.md" in names
    assert "KV_CACHE.md" in names


def test_docs_references_resolve():
    errors = collect_errors()
    assert not errors, "stale documentation references:\n" + "\n".join(errors)
