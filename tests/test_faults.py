"""Churn-tolerant serving tests (ISSUE 8): fault injection, verify
deadlines, and exact request migration off failed draft servers.

Layers under test:
  * ``repro.serving.faults`` — FaultEvent/FaultPlan validation and the
    per-round dense compilation (overlapping windows multiply), plus the
    HealthTracker healthy -> suspect -> down state machine and its
    GOODSPEED-SCHED cap masking;
  * the jit'd round's DEADLINE semantics — a server whose simulated
    chunk arrival blows ``RoundFaults.deadline`` (or whose payload
    dropped) commits NOTHING that round: zero accepted, no bonus token,
    estimator held, caches rolled back to the committed boundary, while
    every other server's round is byte-identical to a fault-free run;
  * EXACT MIGRATION — under ``greedy=True`` (deterministic greedy
    speculative decoding: the emitted sequence is the target's greedy
    decode, a pure function of the committed context) a drain through a
    crash + rejoin script emits BYTE-IDENTICAL sequences to the
    uninterrupted run, across paged x static caches, jnp x kernel
    backends, and sync x overlap round graphs;
  * block reclamation — a crashed server's paged-KV rows return every
    block to the free list;
  * manager-level conservation under random fault plans — no request
    lost, duplicated, or double-seated (``tests.proptest`` sweeps);
  * the serving-surface input validation satellites.

``make churn-check`` runs this module standalone.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

import conftest
from repro.serving.engine import GoodSpeedEngine, _first_paged_leaf
from repro.serving.faults import (DOWN, HEALTHY, SUSPECT, FaultEvent,
                                  FaultPlan, HealthTracker, RoundFaults)
from repro.serving.request import Request, RequestManager
from tests.proptest import sweep


# ---------------------------------------------------------------------------
# FaultPlan / FaultEvent (host-side, model-free)
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_event_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(round=0, kind="meteor", server=0)
        with pytest.raises(ValueError, match="round must be >= 0"):
            FaultEvent(round=-1, kind="crash", server=0)
        with pytest.raises(ValueError, match="server must be >= 0"):
            FaultEvent(round=0, kind="crash", server=-2)
        with pytest.raises(ValueError, match="factor must be >= 1"):
            FaultEvent(round=0, kind="slowdown", server=0, factor=0.5)
        with pytest.raises(ValueError, match="duration must be >= 1"):
            FaultEvent(round=0, kind="drop", server=0, duration=0)

    def test_plan_validation(self):
        with pytest.raises(ValueError, match="deadline must be > 0"):
            FaultPlan(deadline=0.0)
        with pytest.raises(ValueError, match="k_down must be >= 1"):
            FaultPlan(k_down=0)
        with pytest.raises(ValueError, match="suspect_haircut"):
            FaultPlan(suspect_haircut=1.5)
        with pytest.raises(ValueError, match="must be FaultEvent"):
            FaultPlan(events=("crash",))

    def test_round_faults_windows(self):
        plan = FaultPlan(events=(
            FaultEvent(round=2, kind="slowdown", server=0, factor=3.0,
                       duration=2),
            FaultEvent(round=3, kind="slowdown", server=0, factor=2.0),
            FaultEvent(round=2, kind="uplink", server=1, factor=5.0),
            FaultEvent(round=2, kind="drop", server=1),
            # out-of-range server: skipped, not an error (a plan may be
            # reused across engine sizes)
            FaultEvent(round=2, kind="drop", server=9),
        ), deadline=0.5)
        rf1 = plan.round_faults(1, 2)
        np.testing.assert_array_equal(rf1.slow, [1.0, 1.0])
        assert not rf1.dropped.any()
        assert float(rf1.deadline) == pytest.approx(0.5)
        # overlapping slowdown windows on one server multiply
        rf3 = plan.round_faults(3, 2)
        np.testing.assert_allclose(rf3.slow, [6.0, 1.0])
        rf2 = plan.round_faults(2, 2)
        np.testing.assert_allclose(rf2.uplink, [1.0, 5.0])
        np.testing.assert_array_equal(rf2.dropped, [False, True])
        assert plan.horizon() == 4

    def test_crash_rejoin_queries_and_nominal(self):
        plan = FaultPlan(events=(
            FaultEvent(round=1, kind="crash", server=0),
            FaultEvent(round=4, kind="rejoin", server=0),
        ))
        assert plan.crashes_at(1) == [0] and plan.crashes_at(2) == []
        assert plan.rejoins_at(4) == [0]
        nom = RoundFaults.nominal(3)
        assert math.isinf(float(nom.deadline))
        np.testing.assert_array_equal(nom.slow, np.ones(3))

    def test_random_plan_crashes_pair_with_rejoins(self):
        for seed in range(20):
            plan = FaultPlan.random_plan(np.random.default_rng(seed),
                                         n_servers=3, rounds=16)
            crashes = {(e.server, e.round) for e in plan.events
                       if e.kind == "crash"}
            rejoins = {e.server: e.round for e in plan.events
                       if e.kind == "rejoin"}
            for srv, r in crashes:
                assert srv in rejoins and rejoins[srv] > r, plan


# ---------------------------------------------------------------------------
# HealthTracker state machine
# ---------------------------------------------------------------------------

class TestHealthTracker:
    def test_miss_streak_to_down_and_recovery(self):
        t = HealthTracker(2, k_down=3)
        drafted = np.array([True, True])
        t.observe_round(drafted, np.array([True, False]))
        assert t.status == [SUSPECT, HEALTHY]
        t.observe_round(drafted, np.array([True, False]))
        assert t.status == [SUSPECT, HEALTHY]
        # an on-time round clears the streak before the third miss
        t.observe_round(drafted, np.array([False, False]))
        assert t.status == [HEALTHY, HEALTHY]
        assert t.miss_streak[0] == 0
        for _ in range(3):
            t.observe_round(drafted, np.array([True, False]))
        assert t.status == [DOWN, HEALTHY]
        assert t.take_newly_down() == [0]
        assert t.take_newly_down() == []          # reported exactly once
        # DOWN holds without a rejoin, even through on-time observations
        t.observe_round(drafted, np.array([False, False]))
        assert t.status[0] == DOWN
        assert t.rejoin(0) is True                # was down: re-warm
        assert t.status[0] == HEALTHY
        assert t.rejoin(0) is False               # already up: no re-warm

    def test_crash_is_immediate_and_undrafted_holds(self):
        t = HealthTracker(2, k_down=3)
        t.crash(1)
        assert t.status == [HEALTHY, DOWN] and t.take_newly_down() == [1]
        # a server that did not draft holds its state (no false on-time)
        t.observe_round(np.array([True, True]),
                        np.array([True, False]))
        assert t.status == [SUSPECT, DOWN]
        t.observe_round(np.array([False, False]),
                        np.array([False, False]))
        assert t.status == [SUSPECT, DOWN]        # held, not healed
        np.testing.assert_array_equal(t.available(), [True, False])

    def test_apply_caps_masks_down_and_haircuts_suspect(self):
        t = HealthTracker(3, k_down=2, suspect_haircut=0.5)
        t.crash(0)
        t.observe_round(np.array([False, True, True]),
                        np.array([False, True, False]))
        assert t.status == [DOWN, SUSPECT, HEALTHY]
        caps = np.full((6,), 7, np.int32)         # lanes=2, s_max=4
        out = t.apply_caps(caps, lanes=2, s_max=4)
        np.testing.assert_array_equal(out, [0, 0, 2, 2, 7, 7])
        # the original caps array is untouched (copy semantics)
        np.testing.assert_array_equal(caps, 7)


# ---------------------------------------------------------------------------
# engine-level deadline semantics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fault_engine(serve_pair):
    """Two identical 2-server engines + a shared init state builder, so a
    faulted round can be diffed row-by-row against a fault-free one."""
    dm, tm, dp, tp = serve_pair

    def make(**kw):
        kwargs = dict(draft_model=dm, target_model=tm, n_servers=2, C=8,
                      s_max=4, cache_len=128)
        kwargs.update(kw)
        eng = GoodSpeedEngine(**kwargs)
        prompts = [np.arange(1, 7, dtype=np.int32) + 3 * i
                   for i in range(eng.n_rows)]
        state = eng.init(jax.random.PRNGKey(5), prompts, dp, tp)
        return eng, state

    return make, dp, tp


class TestDeadlineRound:
    def test_dropped_server_commits_nothing(self, fault_engine):
        make, dp, tp = fault_engine
        eng_a, st_a = make()
        eng_b, st_b = make()
        faults = RoundFaults.nominal(2)
        faults.dropped[1] = True
        clean_st, clean = eng_a.run_round(st_a, dp, tp)
        hit_st, hit = eng_b.run_round(st_b, dp, tp, faults=faults)

        # the missed server: no emissions, no commit, pending held
        assert bool(hit.missed[1]) and not bool(hit.missed[0])
        assert (hit.emitted[1] == -1).all()
        assert hit.realized[1] == 0.0
        # verify always emits at least the bonus token on a live row, so
        # the dropped row committed strictly less than the clean run's
        assert int(hit_st.length[1]) < int(clean_st.length[1])
        # estimator HELD for the missed server (hold-on-unobserved),
        # updated for the healthy one
        assert float(hit.alpha_hat[1]) == pytest.approx(
            eng_b.estimator.alpha_init)
        assert float(hit.alpha_hat[0]) == pytest.approx(
            float(clean.alpha_hat[0]))
        # the healthy server's row is byte-identical to the clean run
        np.testing.assert_array_equal(hit.emitted[0], clean.emitted[0])
        assert int(hit_st.pending[0]) == int(clean_st.pending[0])
        assert int(hit_st.length[0]) == int(clean_st.length[0])
        # next round's prev_S records what verify actually saw
        assert int(hit_st.S[1]) == 0

    def test_dropped_round_recovers_next_round(self, fault_engine):
        """Under greedy decoding a dropped round is self-healing: the next
        round re-drafts from the same committed context and the emitted
        STREAM equals the uninterrupted run's (rounds shift, bytes
        don't)."""
        make, dp, tp = fault_engine
        eng_a, st_a = make(greedy=True)
        eng_b, st_b = make(greedy=True)

        def stream(hist, row):
            return [int(t) for h in hist for t in h.emitted[row] if t >= 0]

        clean_hist, hit_hist = [], []
        faults = RoundFaults.nominal(2)
        faults.dropped[1] = True
        for r in range(4):
            st_a, s = eng_a.run_round(st_a, dp, tp)
            clean_hist.append(s)
            st_b, s = eng_b.run_round(st_b, dp, tp,
                                      faults=faults if r == 1 else None)
            hit_hist.append(s)
        for row in range(2):
            c, h = stream(clean_hist, row), stream(hit_hist, row)
            assert h == c[:len(h)], f"row {row} diverged"
        # the faulted run lost exactly one round of server 1's progress
        assert len(stream(hit_hist, 1)) < len(stream(clean_hist, 1))

    def test_straggler_misses_finite_deadline(self, fault_engine):
        """A x50 slowdown against a deadline the nominal servers meet
        easily: the straggler misses, the healthy server does not, and
        the simulated receive time is capped AT the deadline."""
        make, dp, tp = fault_engine
        eng, st = make()
        faults = RoundFaults.nominal(2, deadline=0.12)
        faults.slow[1] = 50.0
        st, stats = eng.run_round(st, dp, tp, faults=faults)
        assert bool(stats.missed[1]) and not bool(stats.missed[0])
        assert stats.arrival[1] > 0.12 and stats.arrival[0] < 0.12
        assert float(stats.wall[1]) <= 0.12 + 1e-6   # receive capped

    def test_nominal_faults_are_a_bitwise_noop(self, fault_engine):
        """Passing explicit all-nominal RoundFaults must not change ONE
        bit of the round output vs faults=None (the masking identities
        the fault-free golden traces rely on)."""
        make, dp, tp = fault_engine
        eng_a, st_a = make()
        eng_b, st_b = make()
        st_a, clean = eng_a.run_round(st_a, dp, tp)
        st_b, nomi = eng_b.run_round(st_b, dp, tp,
                                     faults=RoundFaults.nominal(2))
        np.testing.assert_array_equal(clean.emitted, nomi.emitted)
        np.testing.assert_array_equal(clean.alpha_hat, nomi.alpha_hat)
        np.testing.assert_array_equal(clean.wall, nomi.wall)
        np.testing.assert_array_equal(np.asarray(st_a.pending),
                                      np.asarray(st_b.pending))


# ---------------------------------------------------------------------------
# exact migration equivalence (the tentpole invariant)
# ---------------------------------------------------------------------------

CHURN_PLAN = FaultPlan(events=(
    FaultEvent(round=3, kind="crash", server=1),
    FaultEvent(round=9, kind="rejoin", server=1),
    FaultEvent(round=5, kind="drop", server=0, duration=1),
), deadline=0.12, k_down=3)

# (paged_kv, attn_backend, overlap): the acceptance matrix.  The jnp
# sync cells run in tier-1 fast; kernel and overlap cells carry the
# slow marker (CPU interpret-mode kernels).
MIGRATION_GRID = [
    pytest.param(False, "jnp", False, id="static-jnp-sync"),
    pytest.param(True, "jnp", False, id="paged-jnp-sync"),
    pytest.param(False, "jnp", True, id="static-jnp-overlap",
                 marks=pytest.mark.slow),
    pytest.param(True, "jnp", True, id="paged-jnp-overlap",
                 marks=pytest.mark.slow),
    pytest.param(False, "kernel", False, id="static-kernel-sync",
                 marks=pytest.mark.slow),
    pytest.param(True, "kernel", False, id="paged-kernel-sync",
                 marks=pytest.mark.slow),
    pytest.param(False, "kernel", True, id="static-kernel-overlap",
                 marks=pytest.mark.slow),
    pytest.param(True, "kernel", True, id="paged-kernel-overlap",
                 marks=pytest.mark.slow),
]


def _drain(serve_pair, faults=None, *, rounds=80, requests=7, **engine_kw):
    dm, tm, dp, tp = serve_pair
    kw = dict(draft_model=dm, target_model=tm, n_servers=2, C=8, s_max=4,
              cache_len=128, kv_block_size=16, greedy=True)
    kw.update(engine_kw)
    eng = GoodSpeedEngine(**kw)
    rep = eng.serve_requests(jax.random.PRNGKey(0),
                             conftest.mixed_trace_requests(requests),
                             dp, tp, rounds=rounds, faults=faults)
    return eng, rep


class TestMigrationEquivalence:
    @pytest.mark.parametrize("paged,backend,overlap", MIGRATION_GRID)
    def test_crash_rejoin_byte_identical(self, serve_pair, paged, backend,
                                         overlap):
        """The tentpole invariant: a drain interrupted by a crash (exact
        migration + re-admission re-prefill from the committed prefix), a
        rejoin, and a deadline-dropped round emits BYTE-IDENTICAL
        accepted-token sequences to the uninterrupted run, loses zero
        requests, and completes them all."""
        _, base = _drain(serve_pair, None, paged_kv=paged,
                         attn_backend=backend, overlap=overlap)
        _, rep = _drain(serve_pair, CHURN_PLAN, paged_kv=paged,
                        attn_backend=backend, overlap=overlap)
        assert base["summary"]["completed"] == 7
        assert rep["summary"]["completed"] == 7
        assert rep["summary"]["requests_lost"] == 0
        assert rep["summary"]["migrations"] >= 1   # the crash moved work
        assert conftest.generated_seqs(rep) == conftest.generated_seqs(base)

    def test_rejoin_rewarms_estimator(self, serve_pair):
        """While DOWN the server's estimator is quarantined (caps masked
        to zero -> unobserved -> held); the scripted rejoin resets it to
        the cold init so placement treats the returnee as unproven."""
        eng, rep = _drain(serve_pair, CHURN_PLAN)
        est = rep["state"].est
        assert rep["summary"]["faults"]["rejoin_events"] >= 1
        # server 1 drafted again after its round-9 rejoin, so its
        # estimate moved off the re-warm init by the drain's end — the
        # pre-crash history is gone either way; what we can assert
        # exactly is the baseline: a full drain leaves BOTH servers with
        # observed (non-init) estimates
        assert est.alpha_hat.shape == (2,)

    def test_no_mitigation_baseline_loses_requests(self, serve_pair):
        """migrate=False models the unmitigated system: the crashed
        server's seated requests are flagged lost and never complete."""
        plan = dataclasses.replace(CHURN_PLAN, deadline=float("inf"),
                                   migrate=False,
                                   events=(FaultEvent(round=3, kind="crash",
                                                      server=1),))
        _, rep = _drain(serve_pair, plan, rounds=40)
        s = rep["summary"]
        assert s["requests_lost"] >= 1
        assert s["completed"] < 7
        # lost requests still hold their lanes: the manager reports them
        # active but with zero remaining cap
        mgr = rep["manager"]
        lost = [r for r in mgr.active if r is not None and r.lost]
        assert lost and all(not r.done for r in lost)

    def test_suspect_haircut_shrinks_budget(self, serve_pair):
        """A SUSPECT server (one deadline miss) drafts under the haircut
        cap next round instead of being evicted."""
        plan = FaultPlan(events=(
            FaultEvent(round=2, kind="drop", server=0, duration=1),
        ), deadline=0.12, k_down=3, suspect_haircut=0.25)
        _, rep = _drain(serve_pair, plan)
        missed_rounds = [i for i, h in enumerate(rep["rounds"])
                         if h.missed is not None and h.missed[0]]
        assert missed_rounds, "the scripted drop never landed"
        r = missed_rounds[0] + 1
        if r < len(rep["rounds"]):
            # haircut cap: ceil(4 * 0.25) = 1 draft max on server 0
            assert rep["rounds"][r].S[0] <= 1
        assert rep["summary"]["completed"] == 7


# ---------------------------------------------------------------------------
# paged-KV block reclamation on crash
# ---------------------------------------------------------------------------

class TestBlockReclamation:
    def test_crashed_server_blocks_return_to_free_list(self, serve_pair):
        """Crash with NO rejoin under a lazy placement: the victims
        migrate to the surviving server, the crashed server's rows free
        every pool block, and the drain still completes everything."""
        plan = FaultPlan(events=(
            FaultEvent(round=3, kind="crash", server=1),
        ), deadline=0.12, k_down=3)
        eng, rep = _drain(serve_pair, plan, placement="jsq", paged_kv=True)
        assert rep["summary"]["completed"] == 7
        assert rep["summary"]["requests_lost"] == 0
        state = rep["state"]
        for cache in (state.target_cache, state.draft_cache):
            leaf = _first_paged_leaf(cache)
            table = np.asarray(leaf.table)
            # crashed server's row(s): no block table entries remain
            assert (table[1] < 0).all()
            # free-list conservation: every block is free or referenced
            # by exactly one row slot
            free = np.asarray(leaf.free)
            held = table[table >= 0]
            assert len(held) == len(set(held.tolist()))
            assert not free[held].any()
            assert free.sum() + len(held) == free.shape[0]

    @pytest.mark.slow
    def test_reclamation_under_lanes_and_overlap(self, serve_pair):
        plan = FaultPlan(events=(
            FaultEvent(round=3, kind="crash", server=0),
            FaultEvent(round=10, kind="rejoin", server=0),
        ), deadline=0.12)
        _, rep = _drain(serve_pair, plan, placement="jsq", paged_kv=True,
                        lanes=2, overlap=True, requests=9,
                        rounds=100)
        assert rep["summary"]["completed"] == 9
        assert rep["summary"]["requests_lost"] == 0


# ---------------------------------------------------------------------------
# manager-level conservation under random fault plans (model-free)
# ---------------------------------------------------------------------------

def _all_requests(mgr):
    return (list(mgr.arrivals) + [r for q in mgr.queues for r in q]
            + [r for r in mgr.active if r is not None] + mgr.completed)


@sweep(cases=40, seed=20)
def test_manager_conservation_under_random_churn(draw):
    """Drive the RequestManager host loop (no models) through a random
    fault plan: every submitted request is, at every round, in EXACTLY
    one place (global queue, server queue, a single active lane, or
    completed) and the recoverable plan drains completely."""
    n = draw.integers(2, 4)
    lanes = draw.integers(1, 2)
    rounds = draw.integers(12, 30)
    k = draw.integers(3, 12)
    placement = draw.choice(["static", "jsq", "goodput"])
    plan = FaultPlan.random_plan(
        np.random.default_rng(draw.integers(0, 10_000)), n, rounds,
        p_crash=0.6, p_window=0.5)
    tracker = HealthTracker(n, k_down=plan.k_down)
    mgr = RequestManager(n, placement=placement, lanes=lanes)
    reqs = [Request(prompt=np.ones(3, np.int32),
                    max_new_tokens=draw.integers(1, 5)) for _ in range(k)]
    submitted = []
    for r in range(rounds * 3 + 40):
        for srv in plan.crashes_at(r):
            tracker.crash(srv)
        for srv in plan.rejoins_at(r):
            tracker.rejoin(srv)
        for srv in tracker.take_newly_down():
            mgr.evict_server(srv)
        mgr.set_available(tracker.available())
        if r < len(reqs):
            mgr.submit(r % n, reqs[r])
            submitted.append(reqs[r])
        mgr.admit()
        # conservation: each submitted request in exactly one place
        everywhere = _all_requests(mgr)
        assert len(everywhere) == len(submitted)
        assert {id(q) for q in everywhere} == {id(q) for q in submitted}
        seated = [q for q in mgr.active if q is not None]
        assert len({id(q) for q in seated}) == len(seated)  # no double-seat
        # no request seated on a DOWN server
        avail = tracker.available()
        for row, q in enumerate(mgr.active):
            assert q is None or avail[mgr.server_of(row)]
        # emit one token per active request per round
        emitted = np.full((mgr.rows, 2), -1, np.int64)
        for row, q in enumerate(mgr.active):
            if q is not None:
                emitted[row, 0] = 1
        mgr.record_emitted(emitted)
        mgr.retire_done()
        if len(mgr.completed) == k:
            break
    assert len(mgr.completed) == k, \
        (f"recoverable plan did not drain: {len(mgr.completed)}/{k} "
         f"(statuses {tracker.status})")


def test_evict_server_preserves_age_order():
    """Migrated requests re-enter the GLOBAL queue sorted by age —
    ``_oldest_candidate`` peeks only the deque head."""
    mgr = RequestManager(2, placement="jsq")
    old = Request(prompt=np.ones(3, np.int32), max_new_tokens=4)
    mgr.submit(None, old)
    mgr.admit()                                   # old seats on server 0
    assert mgr.active[0] is old
    mgr.round = 3
    young = Request(prompt=np.ones(3, np.int32), max_new_tokens=4)
    mgr.submit(None, young)
    freed = mgr.evict_server(0)
    assert freed == [0] and old.migrations == 1
    assert [r.request_id for r in mgr.arrivals] \
        == [old.request_id, young.request_id]


# ---------------------------------------------------------------------------
# input-validation satellites
# ---------------------------------------------------------------------------

class TestValidation:
    def test_submit_rejects_bad_server_and_cap(self):
        mgr = RequestManager(2)
        with pytest.raises(ValueError, match="out of range"):
            mgr.submit(2, Request(prompt=np.ones(2, np.int32),
                                  max_new_tokens=3))
        with pytest.raises(ValueError, match="out of range"):
            mgr.submit(-1, Request(prompt=np.ones(2, np.int32),
                                   max_new_tokens=3))
        with pytest.raises(ValueError, match="non-positive"):
            mgr.submit(0, Request(prompt=np.ones(2, np.int32),
                                  max_new_tokens=0))
        with pytest.raises(ValueError, match="static placement"):
            mgr.submit(None, Request(prompt=np.ones(2, np.int32),
                                     max_new_tokens=3))

    def test_manager_ctor_validation(self):
        with pytest.raises(ValueError, match="lanes must be >= 1"):
            RequestManager(2, lanes=0)
        with pytest.raises(ValueError, match="n_servers must be >= 1"):
            RequestManager(0)
        with pytest.raises(ValueError, match="availability mask"):
            RequestManager(2).set_available(np.ones(3, bool))

    def test_engine_ctor_validation(self, serve_pair):
        dm, tm, _, _ = serve_pair
        kw = dict(draft_model=dm, target_model=tm, n_servers=2, C=8,
                  s_max=4, cache_len=128)
        with pytest.raises(ValueError, match="lanes must be >= 1"):
            GoodSpeedEngine(lanes=0, **kw)
        with pytest.raises(ValueError, match="attn_backend"):
            GoodSpeedEngine(attn_backend="tpu", **kw)
        with pytest.raises(ValueError, match="unknown placement"):
            GoodSpeedEngine(placement="round-robin", **kw)
        with pytest.raises(ValueError, match="Unknown policy|unknown"):
            GoodSpeedEngine(policy="nope", **kw)


# ---------------------------------------------------------------------------
# benchmark JSON merge hardening satellite
# ---------------------------------------------------------------------------

class TestBenchJsonMerge:
    def _merge(self, tmp_path, monkeypatch, contents):
        import benchmarks.serve_requests as bench
        path = tmp_path / "BENCH_serve.json"
        if contents is not None:
            path.write_text(contents)
        monkeypatch.setattr(bench, "BENCH_JSON", path)
        bench._merge_bench_json({"new_section": {"x": 1}})
        return path

    def test_truncated_json_backed_up_and_merge_succeeds(
            self, tmp_path, monkeypatch, capsys):
        import json
        path = self._merge(tmp_path, monkeypatch, '{"serve": {"a"')
        data = json.loads(path.read_text())
        assert data == {"new_section": {"x": 1}}
        backup = path.with_suffix(".json.corrupt")
        assert backup.exists() and backup.read_text() == '{"serve": {"a"'
        assert "not valid JSON" in capsys.readouterr().err

    def test_non_object_json_backed_up(self, tmp_path, monkeypatch):
        import json
        path = self._merge(tmp_path, monkeypatch, '[1, 2, 3]')
        assert json.loads(path.read_text()) == {"new_section": {"x": 1}}
        assert path.with_suffix(".json.corrupt").exists()

    def test_valid_json_still_merges(self, tmp_path, monkeypatch):
        import json
        path = self._merge(tmp_path, monkeypatch, '{"keep": true}')
        data = json.loads(path.read_text())
        assert data == {"keep": True, "new_section": {"x": 1}}
        assert not path.with_suffix(".json.corrupt").exists()

    def test_missing_file_fresh_start(self, tmp_path, monkeypatch):
        import json
        path = self._merge(tmp_path, monkeypatch, None)
        assert json.loads(path.read_text()) == {"new_section": {"x": 1}}
