"""Sampling warpers + request lifecycle management."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.speculative import acceptance_probability, verify
from repro.serving.request import Request, RequestManager
from repro.serving.sampling import SamplingParams, sample, warp_logits
from tests.proptest import sweep


class TestWarpers:
    @sweep(cases=20, seed=40)
    def test_topk_keeps_k(self, draw):
        v = draw.integers(8, 64)
        k = draw.integers(1, v - 1)
        rng = np.random.default_rng(draw.integers(0, 999))
        logits = jnp.asarray(rng.normal(size=(v,)) * 3, jnp.float32)
        out = warp_logits(logits, SamplingParams(top_k=k))
        assert int(jnp.sum(out > -1e29)) == k

    def test_topp_mass(self):
        logits = jnp.log(jnp.asarray([0.5, 0.3, 0.15, 0.05]))
        out = warp_logits(logits, SamplingParams(top_p=0.8))
        kept = np.asarray(out > -1e29)
        # smallest prefix reaching 0.8 = {0.5, 0.3}
        assert kept.tolist() == [True, True, False, False]

    def test_min_p(self):
        logits = jnp.log(jnp.asarray([0.6, 0.3, 0.05, 0.05]))
        out = warp_logits(logits, SamplingParams(min_p=0.2))
        kept = np.asarray(out > -1e29)
        assert kept.tolist() == [True, True, False, False]  # 0.05 < 0.2*0.6

    def test_temperature_flattens(self):
        logits = jnp.asarray([2.0, 0.0])
        hot = jax.nn.softmax(warp_logits(logits, SamplingParams(
            temperature=4.0)))
        cold = jax.nn.softmax(warp_logits(logits, SamplingParams(
            temperature=0.25)))
        assert float(hot[0]) < float(jax.nn.softmax(logits)[0]) \
            < float(cold[0])

    def test_warped_q_losslessness(self):
        """Speculative decoding with a top-k-warped draft stays lossless iff
        q = the WARPED distribution (acceptance uses the true q)."""
        rng = np.random.default_rng(7)
        v, trials = 16, 4000
        q_raw = jnp.asarray(rng.normal(size=(v,)) * 2, jnp.float32)
        p_l = jnp.asarray(rng.normal(size=(v,)) * 2, jnp.float32)
        q_warp = warp_logits(q_raw, SamplingParams(top_k=4))
        keys = jax.random.split(jax.random.PRNGKey(0), trials)
        toks = jax.vmap(lambda k: sample(k, q_warp))(keys)[:, None]
        q_b = jnp.tile(q_warp, (trials, 1, 1))
        p_b = jnp.tile(p_l, (trials, 2, 1))
        res = verify(jax.random.PRNGKey(1), toks, q_b, p_b,
                     jnp.ones((trials,), jnp.int32))
        first = np.asarray(res.emitted[:, 0])
        p0 = np.asarray(jax.nn.softmax(p_l))
        counts = np.bincount(first, minlength=v) / trials
        sigma = np.sqrt(p0 * (1 - p0) / trials)
        assert np.all(np.abs(counts - p0) < 4.5 * sigma + 6e-3)


class TestRequestManager:
    def _mk(self, n=2):
        rm = RequestManager(n)
        for i in range(n):
            rm.submit(i, Request(prompt=np.arange(4, dtype=np.int32),
                                 max_new_tokens=5))
        return rm

    def test_admission_fifo(self):
        rm = self._mk()
        rm.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=3))
        fresh = rm.admit()
        assert fresh == [0, 1]
        assert rm.active[0].max_new_tokens == 5  # first submitted first

    def test_remaining_caps_and_completion(self):
        rm = self._mk()
        rm.admit()
        np.testing.assert_array_equal(rm.remaining_caps(), [5, 5])
        emitted = np.asarray([[1, 2, 3, -1], [7, -1, -1, -1]], np.int32)
        rm.record_emitted(emitted)
        np.testing.assert_array_equal(rm.remaining_caps(), [2, 4])
        rm.record_emitted(np.asarray([[4, 5, 6, 9], [8, -1, -1, -1]],
                                     np.int32))
        assert rm.active[0].done          # capped at 5 generated
        assert rm.active[0].generated == [1, 2, 3, 4, 5]
        assert not rm.active[1].done

    def test_done_request_retires_with_empty_queue(self):
        """A finished request must move to ``completed`` even when no
        successor is queued — the slot goes idle, not zombie."""
        rm = RequestManager(1)
        rm.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=2))
        rm.admit()
        rm.record_emitted(np.asarray([[7, 9, -1]], np.int32))
        assert rm.active[0].done
        fresh = rm.admit()                 # queue is EMPTY
        assert fresh == []
        assert rm.active[0] is None
        st = rm.stats()
        assert st["completed"] == 1
        assert st["active"] == 0
        np.testing.assert_array_equal(rm.remaining_caps(), [0])

    def test_eos_truncates_generated(self):
        """Tokens past the first EOS never enter ``generated``: remaining,
        goodput accounting and returned text stay consistent with done."""
        rm = RequestManager(1)
        rm.submit(0, Request(prompt=np.zeros(2, np.int32),
                             max_new_tokens=10, eos_token=42))
        rm.admit()
        rm.record_emitted(np.asarray([[5, 42, 7, 8]], np.int32))
        req = rm.active[0]
        assert req.generated == [5, 42]    # EOS kept, tail dropped
        assert req.done
        assert req.remaining == 8          # consistent with truncation
        np.testing.assert_array_equal(rm.remaining_caps(), [0])

    def test_admit_round_recorded(self):
        rm = RequestManager(1)
        rm.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=2))
        rm.record_emitted(np.asarray([[-1]], np.int32))   # a round passes
        rm.admit()
        assert rm.active[0].arrival_round == 0
        assert rm.active[0].admit_round == 1

    def test_tick_ages_queued_unplaced_requests(self):
        """All-idle rounds (tick) age requests still waiting in the global
        arrival queue AND in per-server queues — wait metrics are honest
        even before a request is ever placed."""
        rm = RequestManager(1)
        req = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        rm.submit(0, req)
        rm.tick()                          # still in the arrival queue
        rm.tick()
        assert req.queue_wait == 2
        assert rm.stats()["queue_wait_ticks"][req.request_id] == 2
        fresh = rm.admit()                 # placed + admitted at round 2
        assert fresh == [0]
        assert req.admit_round - req.arrival_round == req.queue_wait == 2

    def test_stats_reports_per_request_wait_and_per_server(self):
        rm = RequestManager(2)
        a = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        b = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        c = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        rm.submit(0, a)
        rm.submit(0, b)                    # queued behind a on server 0
        rm.submit(1, c)
        rm.admit()
        rm.record_emitted(np.asarray([[7, -1], [8, 9]], np.int32))
        st = rm.stats()
        assert st["queue_wait_ticks"] == {a.request_id: 0,
                                          b.request_id: 1,
                                          c.request_id: 0}
        assert st["per_server_admitted"] == [1, 1]
        assert st["queued"] == 1

    def test_eos_completion_and_refill(self):
        rm = RequestManager(1)
        rm.submit(0, Request(prompt=np.zeros(2, np.int32),
                             max_new_tokens=10, eos_token=42))
        rm.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=4))
        rm.admit()
        rm.record_emitted(np.asarray([[5, 42, -1]], np.int32))
        assert rm.active[0].done
        fresh = rm.admit()                 # next request admitted
        assert fresh == [0]
        assert rm.active[0].max_new_tokens == 4
        st = rm.stats()
        assert st["completed"] == 1
        assert st["mean_latency_rounds"] >= 0
