"""TPU budget derivation + discrete-event latency model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.budget import V5E, derive_budget, ridge_tokens
from repro.core.latency import LatencyModel


class TestBudget:
    def test_ridge_point(self):
        # bf16: 2 bytes/param -> T* = peak/bw = ~240
        assert ridge_tokens(2) == pytest.approx(241, abs=2)
        assert ridge_tokens(4) == pytest.approx(2 * ridge_tokens(2), abs=2)

    def test_memory_cap_binds_for_big_models(self):
        # 70B params on few chips: memory-capped below the knee
        c_small = derive_budget(8, params=70e9, kv_bytes_per_token=5e5,
                                max_prefix_len=4096, chips=8)
        c_big = derive_budget(8, params=70e9, kv_bytes_per_token=5e5,
                              max_prefix_len=4096, chips=64)
        assert c_big >= c_small
        assert c_small >= 8  # never below one slot per server

    def test_monotone_in_chips(self):
        cs = [derive_budget(4, 14e9, 2e5, 2048, chips=c)
              for c in (1, 2, 4, 8)]
        assert all(a <= b for a, b in zip(cs, cs[1:]))


class TestLatency:
    def setup_method(self):
        self.lm = LatencyModel()
        self.S = jnp.asarray([4, 2, 6, 0])
        self.jit = jnp.zeros((4,))

    def test_receive_is_max_over_servers(self):
        t = float(self.lm.receive_time(self.S, 32000, self.jit))
        t_each = [float(self.lm.draft_time(jnp.asarray([s]),
                                           jnp.zeros(1))[0])
                  + float(self.lm.uplink_payload(jnp.asarray([s]),
                                                 32000)[0])
                  / self.lm.uplink_bytes_s + self.lm.rtt_s
                  for s in [4, 2, 6]]
        assert t == pytest.approx(max(t_each), rel=1e-5)

    def test_verify_time_roofline(self):
        # tiny T: memory-bound (flat); huge T: compute-bound (linear)
        t_small = float(self.lm.verify_time(jnp.asarray([1, 1])))
        t_small2 = float(self.lm.verify_time(jnp.asarray([2, 2])))
        assert t_small == pytest.approx(t_small2)  # below the knee
        big = jnp.full((8,), 10_000)
        t_big = float(self.lm.verify_time(big))
        t_big2 = float(self.lm.verify_time(big * 2))
        assert t_big2 == pytest.approx(2 * t_big, rel=0.01)

    def test_topk_truncation_shrinks_payload(self):
        full = LatencyModel(probs_topk=0)
        topk = LatencyModel(probs_topk=64)
        pf = float(full.uplink_payload(self.S, 151936).sum())
        pt = float(topk.uplink_payload(self.S, 151936).sum())
        assert pt < pf / 100  # beyond-paper: ~2000x payload cut

    def test_send_tiny(self):
        total, (r, v, s) = self.lm.round_time(self.S, self.S + 1, 32000,
                                              self.jit)
        assert float(s) / float(total) < 0.001

    def test_round_decomposition_pins(self):
        """Pin the synchronous per-round latency law: the round is the
        straight-line SUM receive + verify + send of the components the
        model exposes (the round-graph reconcile prices rounds with
        exactly this decomposition)."""
        total, (r, v, s) = self.lm.round_time(self.S, self.S + 1, 32000,
                                              self.jit)
        assert float(total) == pytest.approx(float(r) + float(v) + float(s),
                                             rel=1e-6)
        assert float(r) == pytest.approx(
            float(self.lm.receive_time(self.S, 32000, self.jit)), rel=1e-6)
        assert float(v) == pytest.approx(
            float(self.lm.verify_time(self.S)), rel=1e-6)
        assert float(s) == pytest.approx(
            float(self.lm.send_time(self.S + 1)), rel=1e-6)

    def test_lane_rows_share_server_uplink(self):
        """Two lanes on one server pay ONE uplink (payloads sum before
        the transfer-time division) and draft in one batched forward
        (draft time = slowest lane) — versus two single-lane servers
        whose transfers overlap (receive = max of the two)."""
        S = jnp.asarray([3, 3])
        shared = float(self.lm.receive_time(S, 32000, jnp.zeros(2),
                                            lanes=2))
        separate = float(self.lm.receive_time(S, 32000, jnp.zeros(2),
                                              lanes=1))
        draft = float(self.lm.draft_time(jnp.asarray([3]), jnp.zeros(1))[0])
        pay = float(self.lm.uplink_payload(jnp.asarray([3]), 32000)[0])
        assert shared == pytest.approx(
            draft + 2 * pay / self.lm.uplink_bytes_s + self.lm.rtt_s,
            rel=1e-6)
        assert separate == pytest.approx(
            draft + pay / self.lm.uplink_bytes_s + self.lm.rtt_s, rel=1e-6)
        assert shared > separate

    def test_overlapped_round_is_max_not_sum(self):
        """PEARL-style overlap: steady-state round time collapses the
        receive/verify SUM to their MAX (drafts for round t are produced
        while round t-1's chunk is in flight); send is still serial."""
        prev_S = jnp.asarray([6, 4, 2, 1])
        ov, (r, v, s) = self.lm.overlapped_round_time(
            self.S, prev_S, self.S + 1, 32000, self.jit)
        assert float(ov) == pytest.approx(
            max(float(r), float(v)) + float(s), rel=1e-6)
        assert float(r) == pytest.approx(
            float(self.lm.receive_time(self.S, 32000, self.jit)), rel=1e-6)
        # verify prices the PREVIOUS round's chunk, not this round's
        assert float(v) == pytest.approx(
            float(self.lm.verify_time(prev_S)), rel=1e-6)
        # overlap never exceeds the synchronous sum of the same parts
        assert float(ov) <= float(r) + float(v) + float(s) + 1e-9

    def test_overlapped_degenerate_prev_zero(self):
        """First round of a serve (nothing in flight): verify(prev_S=0)
        is the weight-streaming floor, so overlap still beats the sum."""
        zeros = jnp.zeros((4,), jnp.int32)
        ov, (r, v, s) = self.lm.overlapped_round_time(
            self.S, zeros, self.S + 1, 32000, self.jit)
        sync, _ = self.lm.round_time(self.S, self.S + 1, 32000, self.jit)
        assert float(ov) <= float(sync)
