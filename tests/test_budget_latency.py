"""TPU budget derivation + discrete-event latency model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.budget import V5E, derive_budget, ridge_tokens
from repro.core.latency import LatencyModel


class TestBudget:
    def test_ridge_point(self):
        # bf16: 2 bytes/param -> T* = peak/bw = ~240
        assert ridge_tokens(2) == pytest.approx(241, abs=2)
        assert ridge_tokens(4) == pytest.approx(2 * ridge_tokens(2), abs=2)

    def test_memory_cap_binds_for_big_models(self):
        # 70B params on few chips: memory-capped below the knee
        c_small = derive_budget(8, params=70e9, kv_bytes_per_token=5e5,
                                max_prefix_len=4096, chips=8)
        c_big = derive_budget(8, params=70e9, kv_bytes_per_token=5e5,
                              max_prefix_len=4096, chips=64)
        assert c_big >= c_small
        assert c_small >= 8  # never below one slot per server

    def test_monotone_in_chips(self):
        cs = [derive_budget(4, 14e9, 2e5, 2048, chips=c)
              for c in (1, 2, 4, 8)]
        assert all(a <= b for a, b in zip(cs, cs[1:]))


class TestLatency:
    def setup_method(self):
        self.lm = LatencyModel()
        self.S = jnp.asarray([4, 2, 6, 0])
        self.jit = jnp.zeros((4,))

    def test_receive_is_max_over_servers(self):
        t = float(self.lm.receive_time(self.S, 32000, self.jit))
        t_each = [float(self.lm.draft_time(jnp.asarray([s]),
                                           jnp.zeros(1))[0])
                  + float(self.lm.uplink_payload(jnp.asarray([s]),
                                                 32000)[0])
                  / self.lm.uplink_bytes_s + self.lm.rtt_s
                  for s in [4, 2, 6]]
        assert t == pytest.approx(max(t_each), rel=1e-5)

    def test_verify_time_roofline(self):
        # tiny T: memory-bound (flat); huge T: compute-bound (linear)
        t_small = float(self.lm.verify_time(jnp.asarray([1, 1])))
        t_small2 = float(self.lm.verify_time(jnp.asarray([2, 2])))
        assert t_small == pytest.approx(t_small2)  # below the knee
        big = jnp.full((8,), 10_000)
        t_big = float(self.lm.verify_time(big))
        t_big2 = float(self.lm.verify_time(big * 2))
        assert t_big2 == pytest.approx(2 * t_big, rel=0.01)

    def test_topk_truncation_shrinks_payload(self):
        full = LatencyModel(probs_topk=0)
        topk = LatencyModel(probs_topk=64)
        pf = float(full.uplink_payload(self.S, 151936).sum())
        pt = float(topk.uplink_payload(self.S, 151936).sum())
        assert pt < pf / 100  # beyond-paper: ~2000x payload cut

    def test_send_tiny(self):
        total, (r, v, s) = self.lm.round_time(self.S, self.S + 1, 32000,
                                              self.jit)
        assert float(s) / float(total) < 0.001
