"""Round-graph overlap tests (ISSUE 6): async draft/verify pipelining.

Covers the three layers of the round-graph refactor:

  * ``core.budget.verify_bucket`` — the canonical jit-static chunk-width
    table (monotone cover, identity beyond the table);
  * ``serving.kv_cache.discard_tail`` / ``snapshot_alloc_flag`` — the
    draft-tail discard primitive the deferred reconcile uses: dropping
    ahead-writes restores the exact synchronous rollback state (static
    and paged, including the sticky ``alloc_failed`` flag snapshot);
  * ``serving.engine.GoodSpeedEngine(overlap=True)`` — the four-phase
    dispatch pipeline (draft -> verify -> draft-ahead -> deferred
    reconcile) lands the IDENTICAL post-round engine state as the
    synchronous composed round, round by round: the ahead tail is
    discarded one round late whenever verification rejects its root
    (and even when it doesn't — the bonus token is only sampled inside
    verify), accepted-token sequences on the ACCEPTANCE mixed trace
    match the recorded golden across paged x static x jnp x kernel,
    and committed caches stay equal to a fresh prefill.

``make overlap-check`` runs this module standalone.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.core.budget import VERIFY_BUCKETS, verify_bucket
from repro.serving.engine import GoodSpeedEngine
from repro.serving.kv_cache import (AttnCache, PagedAttnCache,
                                    discard_tail, init_attn_cache,
                                    init_paged_attn_cache, rollback,
                                    snapshot_alloc_flag, write_chunk)

GOLDEN = conftest.__file__.replace("conftest.py",
                                   "tests/data/mixed_trace_golden.json")


# ---------------------------------------------------------------------------
# verify_bucket
# ---------------------------------------------------------------------------

class TestVerifyBucket:
    def test_table_sorted_unique(self):
        assert list(VERIFY_BUCKETS) == sorted(set(VERIFY_BUCKETS))

    def test_covers_and_monotone(self):
        prev = 0
        for s in range(1, 80):
            b = verify_bucket(s)
            assert b >= s
            assert b >= prev          # non-decreasing in s_max
            prev = b

    def test_table_values_map_to_themselves(self):
        for b in VERIFY_BUCKETS:
            assert verify_bucket(b) == b

    def test_identity_beyond_table(self):
        assert verify_bucket(max(VERIFY_BUCKETS) + 7) \
            == max(VERIFY_BUCKETS) + 7


# ---------------------------------------------------------------------------
# kv_cache: discard_tail == synchronous rollback state
# ---------------------------------------------------------------------------

def _write_tokens(cache, start, count, base=1.0):
    """Append a ``count``-token chunk per row (the cache's own ``next_pos``
    counter places it — ``start`` documents the expected position of the
    first write; deterministic values so buffers are comparable)."""
    b = cache.next_pos.shape[0]
    kv, hd = (cache.k.shape[2:] if isinstance(cache, AttnCache)
              else cache.kpool.shape[2:])
    assert int(cache.next_pos.max()) == start
    chunk = (base + jnp.arange(count, dtype=jnp.float32))[None, :, None,
                                                          None]
    val = jnp.broadcast_to(chunk, (b, count, kv, hd))
    return write_chunk(cache, (val, val), jnp.ones((b, count), bool))


class TestDiscardTail:
    """The deferred reconcile's contract: committed prefix + real draft
    chunk + ahead-writes, then ``discard_tail(keep)`` must equal the
    cache that never drafted ahead and rolled back synchronously."""

    def _check_equal_static(self, got, want):
        m = np.asarray(want.pos_arr) >= 0
        np.testing.assert_array_equal(np.asarray(got.pos_arr),
                                      np.asarray(want.pos_arr))
        np.testing.assert_array_equal(np.asarray(got.next_pos),
                                      np.asarray(want.next_pos))
        for f in ("k", "v"):
            a = np.where(m[..., None, None], np.asarray(getattr(got, f)), 0)
            b = np.where(m[..., None, None], np.asarray(getattr(want, f)), 0)
            np.testing.assert_array_equal(a, b)

    def test_static_matches_sync_rollback(self):
        cache = init_attn_cache(2, 32, 1, 4, jnp.float32)
        cache = _write_tokens(cache, 0, 6)          # committed prefix
        cache = _write_tokens(cache, 6, 4, 10.0)    # real draft chunk
        keep = jnp.asarray([8, 7], jnp.int32)       # accept 2 / 1 tokens
        want = rollback(cache, keep)
        ahead = _write_tokens(cache, 10, 3, 99.0)   # overlap draft-ahead
        got = discard_tail(ahead, keep)
        self._check_equal_static(got, want)

    def test_static_full_accept_drops_ahead_root(self):
        """m == S == s_max: keep equals the post-draft counter, so the
        sync rollback is a no-op past the chunk — but the ahead root
        wrote AT the counter and must still be dropped."""
        cache = _write_tokens(init_attn_cache(1, 32, 1, 4, jnp.float32),
                              0, 10)
        keep = jnp.asarray([10], jnp.int32)
        want = rollback(cache, keep)
        ahead = _write_tokens(cache, 10, 2, 99.0)
        got = discard_tail(ahead, keep)
        self._check_equal_static(got, want)
        assert int(got.pos_arr[0, 10]) == -1

    def _paged_view(self, c):
        """Gather the logical per-row view of a paged cache (valid slots
        only) + the allocator state — the full comparable surface."""
        table, pos = np.asarray(c.table), np.asarray(c.pos_arr)
        bs = c.kpool.shape[1]
        bsz, slots = pos.shape
        out = np.zeros((bsz, slots) + c.kpool.shape[2:], np.float32)
        for b in range(bsz):
            for l in range(slots):
                blk = table[b, l // bs]
                if pos[b, l] >= 0 and blk >= 0:
                    out[b, l] = np.asarray(c.kpool[blk, l % bs])
        return out, table, np.asarray(c.free), pos, \
            np.asarray(c.next_pos), np.asarray(c.alloc_failed)

    def test_paged_matches_sync_rollback(self):
        cache = init_paged_attn_cache(2, 24, 1, 4, jnp.float32,
                                      num_blocks=8, block_size=4)
        cache = _write_tokens(cache, 0, 5)
        cache = _write_tokens(cache, 5, 4, 10.0)
        keep = jnp.asarray([8, 5], jnp.int32)
        want = self._paged_view(discard_tail(cache, keep))
        ahead = _write_tokens(cache, 9, 3, 99.0)
        got = self._paged_view(discard_tail(ahead, keep))
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)

    def test_paged_block_boundary_frees_ahead_blocks(self):
        """keep lands exactly on a block boundary: every block the ahead
        allocated must return to the free list."""
        cache = init_paged_attn_cache(1, 16, 1, 4, jnp.float32,
                                      num_blocks=6, block_size=4)
        cache = _write_tokens(cache, 0, 8)          # fills blocks 0-1
        free_before = np.asarray(cache.free).copy()
        ahead = _write_tokens(cache, 8, 5, 99.0)    # allocates 2 more
        assert np.asarray(ahead.free).sum() < free_before.sum()
        got = discard_tail(ahead, jnp.asarray([8], jnp.int32))
        np.testing.assert_array_equal(np.asarray(got.free), free_before)

    def test_alloc_flag_snapshot_restored(self):
        """Ahead-writes that exhaust the pool set the sticky
        ``alloc_failed`` flag; the deferred discard must restore the
        pre-ahead snapshot so speculative exhaustion never poisons the
        host's admission health checks."""
        cache = init_paged_attn_cache(1, 64, 1, 4, jnp.float32,
                                      num_blocks=3, block_size=4)
        cache = _write_tokens(cache, 0, 10)         # 3 blocks: pool full
        flag = snapshot_alloc_flag(cache)
        assert not bool(flag)
        ahead = _write_tokens(cache, 10, 4, 99.0)   # needs a 4th block
        assert bool(ahead.alloc_failed)             # sticky failure set
        got = discard_tail(ahead, jnp.asarray([10], jnp.int32),
                           alloc_failed=flag)
        assert not bool(got.alloc_failed)

    def test_snapshot_alloc_flag_static_is_none(self):
        assert snapshot_alloc_flag(
            init_attn_cache(1, 8, 1, 4, jnp.float32)) is None


# ---------------------------------------------------------------------------
# engine: overlap round == synchronous round, state-for-state
# ---------------------------------------------------------------------------

def _canon_cache(c):
    """Comparable form of a cache leaf: values at VALID slots only (both
    modes leave garbage past the committed boundary — sync from the real
    over-draft, overlap additionally from the discarded ahead tail — and
    masked slots contribute exactly 0 to attention)."""
    if c.next_pos.ndim == 2:      # layer-stacked leaf: canon each layer
        return [_canon_cache(type(c)(*[f[g] for f in c]))
                for g in range(c.next_pos.shape[0])]
    if isinstance(c, AttnCache):
        m = np.asarray(c.pos_arr) >= 0
        return dict(k=np.where(m[..., None, None], np.asarray(c.k), 0),
                    v=np.where(m[..., None, None], np.asarray(c.v), 0),
                    pos=np.asarray(c.pos_arr), nxt=np.asarray(c.next_pos))
    if isinstance(c, PagedAttnCache):
        table, pos = np.asarray(c.table), np.asarray(c.pos_arr)
        bs = c.kpool.shape[1]
        bsz, slots = pos.shape
        k = np.zeros((bsz, slots) + c.kpool.shape[2:], np.float32)
        v = np.zeros_like(k)
        kp, vp = np.asarray(c.kpool), np.asarray(c.vpool)
        for b in range(bsz):
            for l in range(slots):
                blk = table[b, l // bs]
                if pos[b, l] >= 0 and blk >= 0:
                    k[b, l], v[b, l] = kp[blk, l % bs], vp[blk, l % bs]
        return dict(k=k, v=v, pos=pos, nxt=np.asarray(c.next_pos),
                    table=table, free=np.asarray(c.free),
                    failed=np.asarray(c.alloc_failed))
    return c


def _canon_state(state):
    leaves = jax.tree_util.tree_leaves(
        (state.target_cache, state.draft_cache),
        is_leaf=lambda x: isinstance(x, (AttnCache, PagedAttnCache)))
    canon = []
    for c in leaves:
        out = _canon_cache(c)
        canon.extend(out if isinstance(out, list) else [out])
    return (canon,
            np.asarray(state.pending), np.asarray(state.length),
            np.asarray(state.S), np.asarray(state.key),
            jax.tree.map(np.asarray, state.est))


def _assert_state_equal(a, b, tag):
    ca, pa, la, sa, ka, ea = a
    cb, pb, lb, sb, kb, eb = b
    np.testing.assert_array_equal(pa, pb, err_msg=tag)
    np.testing.assert_array_equal(la, lb, err_msg=tag)
    np.testing.assert_array_equal(sa, sb, err_msg=tag)
    np.testing.assert_array_equal(ka, kb, err_msg=tag)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), ea, eb)
    assert len(ca) == len(cb)
    for x, y in zip(ca, cb):
        for f in x:
            np.testing.assert_array_equal(x[f], y[f],
                                          err_msg=f"{tag}: cache field {f}")


class TestDeferredReconcile:
    """Round-by-round: the overlap pipeline's deferred reconcile restores
    the exact synchronous post-round state — including rounds whose
    verify REJECTS the ahead root (m < S), where the entire speculative
    tail drafted from that root is discarded."""

    @pytest.mark.parametrize("paged", [False, True])
    def test_state_identical_each_round(self, serve_pair, paged):
        dm, tm, dp0, tp0 = serve_pair
        kw = dict(draft_model=dm, target_model=tm, n_servers=2, C=6,
                  s_max=3, cache_len=64, kv_block_size=8, paged_kv=paged)
        prompts = [np.arange(1, 7, dtype=np.int32),
                   np.arange(2, 10, dtype=np.int32)]
        runs = {}
        for overlap in (False, True):
            eng = GoodSpeedEngine(**kw, overlap=overlap)
            state = eng.init(jax.random.PRNGKey(4), prompts, dp0, tp0)
            snaps, rejected_root = [], False
            for _ in range(6):
                state, stats = eng.run_round(state, dp0, tp0)
                snaps.append(_canon_state(state))
                rejected_root |= bool(np.any(stats.accepted < stats.S))
                if overlap:
                    assert stats.wall_overlap > 0.0
                    assert np.all(stats.ahead_S >= 0)
                    assert np.all(stats.ahead_S <= eng.s_bucket)
                    # the overlapped round is never slower than the sum
                    assert stats.wall_overlap <= stats.wall[0] + 1e-6
            runs[overlap] = snaps
            # the trace must actually exercise a rejected overlap root
            assert rejected_root
        for r, (a, b) in enumerate(zip(runs[False], runs[True])):
            _assert_state_equal(a, b, f"round {r} (paged={paged})")

    def test_overlap_cache_matches_fresh_prefill(self, serve_pair):
        """Acceptance pin: after overlap rounds (ahead tails discarded
        every round), the committed caches answer exactly like a
        from-scratch prefill of the committed tokens."""
        dm, tm, dp, tp = serve_pair
        n = 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=96, paged_kv=True,
                              kv_block_size=8, overlap=True)
        prompts = [np.arange(1, 8, dtype=np.int32),
                   np.arange(3, 9, dtype=np.int32)]
        state = eng.init(jax.random.PRNGKey(2), prompts, dp, tp)
        committed = [list(p) for p in prompts]
        for _ in range(4):
            state, stats = eng.run_round(state, dp, tp)
            for i in range(n):
                row = stats.emitted[i]
                committed[i].extend(int(t) for t in row[row >= 0])
        out = tm.forward(tp, state.pending[:, None], mode="decode",
                         cache=state.target_cache,
                         positions=state.length[:, None])
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            err = float(jnp.max(jnp.abs(out.logits[i, 0] - ref)))
            assert err < 3e-3, f"row {i}: cache drift {err}"

    def test_overlap_requires_rollbackable_stacks(self):
        from repro.configs import get_reduced
        from repro.models import Model
        dm = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                               num_heads=2, num_kv_heads=2, head_dim=32,
                               d_ff=128, vocab_size=64))
        tm = Model(get_reduced("xlstm-350m", num_layers=2, d_model=64,
                               num_heads=2, num_kv_heads=2, head_dim=32,
                               d_ff=128, vocab_size=64))
        with pytest.raises(AssertionError, match="rollbackable"):
            GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                            C=6, s_max=3, cache_len=64, overlap=True)

    def test_phase_jits_compile_once(self, serve_pair):
        """Retrace telemetry: a fixed-shape round loop compiles each
        overlap phase exactly once (``round_trace_counts``)."""
        dm, tm, dp, tp = serve_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                              C=6, s_max=3, cache_len=64, overlap=True)
        state = eng.init(jax.random.PRNGKey(0),
                         [np.arange(1, 6, dtype=np.int32)] * 2, dp, tp)
        for r in range(3):
            caps = np.asarray([5, 3 + r], np.int32)  # values vary, shape not
            state, _ = eng.run_round(state, dp, tp, caps=caps)
        counts = eng.round_trace_counts()
        assert set(counts) == {"draft", "verify", "ahead", "reconcile"}
        assert all(v == 1 for v in counts.values()), counts


# ---------------------------------------------------------------------------
# acceptance trace: overlap == golden across cache x backend x lanes
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestOverlapEquivalenceTrace:
    """``GoodSpeedEngine(overlap=True)`` must emit the IDENTICAL
    accepted-token sequences as the synchronous engine on the ACCEPTANCE
    mixed admit/retire/EOS trace — pinned against the same recorded
    golden the sync engine is held to."""

    @pytest.mark.parametrize("paged,backend", [
        (False, "jnp"), (True, "jnp"), (False, "kernel"), (True, "kernel")])
    def test_overlap_matches_recorded_trace(self, mixed_trace, paged,
                                            backend):
        golden = json.load(open(GOLDEN))
        rep = mixed_trace(paged_kv=paged, attn_backend=backend,
                          overlap=True)
        assert conftest.generated_seqs(rep) == golden

    @pytest.mark.parametrize("paged", [False, True])
    def test_overlap_matches_sync_lanes2(self, mixed_trace, paged):
        """Lane rows keep the equivalence too (server-major [N*R] rows,
        ahead budgets water-filled per server like the real round)."""
        ref = mixed_trace(lanes=2, paged_kv=paged)
        rep = mixed_trace(lanes=2, paged_kv=paged, overlap=True)
        assert conftest.generated_seqs(rep) == conftest.generated_seqs(ref)
