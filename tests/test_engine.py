"""End-to-end GoodSpeed serving-engine tests with real transformer models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticDomain, make_workload
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine


def _tiny(arch, vocab=64, **kw):
    base = dict(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                head_dim=32, d_ff=128, vocab_size=vocab)
    base.update(kw)
    cfg = get_reduced(arch, **base)
    return cfg


def _prompts(n, vocab, lo=6, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [SyntheticDomain("alpaca", vocab, i).sample_prompt(rng)
            [: rng.integers(lo, hi)] for i in range(n)]


@pytest.fixture(scope="module")
def dense_pair():
    dm = Model(_tiny("olmo-1b"))
    tm = Model(_tiny("qwen3-8b", d_model=128, num_heads=4, d_ff=256))
    dp = dm.init(jax.random.PRNGKey(0))
    tp = tm.init(jax.random.PRNGKey(1))
    return dm, tm, dp, tp


class TestEngineBasics:
    def test_round_invariants(self, dense_pair):
        dm, tm, dp, tp = dense_pair
        n = 4
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=12, s_max=5, cache_len=128,
                              draft_temps=(1.0, 1.3, 0.8, 1.6))
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(n, 64), dp, tp,
                         rounds=6)
        for h in hist:
            assert h.S.sum() <= 12
            assert np.all(h.S <= 5)
            assert np.all(h.accepted <= h.S)
            assert np.all(h.realized == h.accepted + 1)
            assert np.all((h.alpha_hat > 0) & (h.alpha_hat < 1))
            assert np.isfinite(h.utility)
            assert h.wall[0] > 0
            # emitted rows: m real tokens then the extra token then -1 pad
            for i in range(n):
                row = h.emitted[i]
                m = h.accepted[i]
                assert np.all(row[:m + 1] >= 0)
                assert np.all(row[m + 1:] == -1)

    def test_identical_models_accept_all(self):
        """Losslessness smoke: draft == target => every draft accepted."""
        cfg = _tiny("qwen3-8b")
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        eng = GoodSpeedEngine(draft_model=m, target_model=m, n_servers=3,
                              C=9, s_max=4, cache_len=96)
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(3, 64), p, p,
                         rounds=6)
        for h in hist:
            np.testing.assert_array_equal(h.accepted, h.S)

    def test_cache_matches_fresh_prefill(self, dense_pair):
        """Cache-integrity: after rounds, the engine's next-step logits for
        the committed sequence equal a from-scratch prefill's logits."""
        dm, tm, dp, tp = dense_pair
        n = 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=96)
        prompts = _prompts(n, 64, seed=3)
        state = eng.init(jax.random.PRNGKey(2), prompts, dp, tp)
        committed = [list(p) for p in prompts]
        for _ in range(4):
            state, stats = eng.run_round(state, dp, tp)
            for i in range(n):
                row = stats.emitted[i]
                committed[i].extend(int(t) for t in row[row >= 0])
        # engine view: decode `pending` (last committed token) one step
        pos = state.length[:, None]
        out_eng = tm.forward(tp, state.pending[:, None], mode="decode",
                             cache=state.target_cache, positions=pos)
        # fresh view: full prefill of committed tokens
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            got = out_eng.logits[i, 0]
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 3e-3, f"row {i}: cache drift {err}"

    def test_recompute_path_archs(self):
        """Recurrent/hybrid/sliding targets exercise checkpoint-recompute."""
        for arch in ("xlstm-350m", "recurrentgemma-9b", "h2o-danube-3-4b"):
            tm = Model(_tiny(arch))
            dm = Model(_tiny("olmo-1b"))
            dp = dm.init(jax.random.PRNGKey(0))
            tp = tm.init(jax.random.PRNGKey(1))
            eng = GoodSpeedEngine(draft_model=dm, target_model=tm,
                                  n_servers=2, C=6, s_max=3, cache_len=64)
            hist = eng.serve(jax.random.PRNGKey(2), _prompts(2, 64), dp, tp,
                             rounds=4)
            assert all(np.isfinite(h.utility) for h in hist), arch

    def test_recompute_cache_integrity(self):
        """Cache-integrity under the recompute rollback (sliding window)."""
        tm = Model(_tiny("h2o-danube-3-4b", window=16))
        dm = Model(_tiny("olmo-1b"))
        dp = dm.init(jax.random.PRNGKey(0))
        tp = tm.init(jax.random.PRNGKey(1))
        n = 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=16)
        prompts = _prompts(n, 64, seed=5)
        state = eng.init(jax.random.PRNGKey(2), prompts, dp, tp)
        committed = [list(p) for p in prompts]
        for _ in range(3):
            state, stats = eng.run_round(state, dp, tp)
            for i in range(n):
                row = stats.emitted[i]
                committed[i].extend(int(t) for t in row[row >= 0])
        out_eng = tm.forward(tp, state.pending[:, None], mode="decode",
                             cache=state.target_cache,
                             positions=state.length[:, None])
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            err = float(jnp.max(jnp.abs(out_eng.logits[i, 0] - ref)))
            assert err < 3e-3, f"row {i}: recompute cache drift {err}"


class TestEngineScheduling:
    def test_goodspeed_shifts_budget_to_high_alpha(self):
        """With a shared draft model but very different temperatures, the
        cold-temperature (well-aligned) servers should end up with larger
        allocations under the goodspeed policy."""
        cfg = _tiny("qwen3-8b")
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        n = 4
        # temp 1.0 == target distribution (alpha ~ 1), temp 3.0 mismatched
        eng = GoodSpeedEngine(draft_model=m, target_model=m, n_servers=n,
                              C=16, s_max=8, cache_len=256,
                              draft_temps=(1.0, 1.0, 3.0, 3.0),
                              policy="goodspeed")
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(n, 64), p, p,
                         rounds=12)
        tail = np.mean([h.S for h in hist[-4:]], axis=0)
        assert tail[:2].mean() > tail[2:].mean(), tail
        ah = hist[-1].alpha_hat
        assert ah[:2].mean() > ah[2:].mean(), ah
