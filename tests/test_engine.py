"""End-to-end GoodSpeed serving-engine tests with real transformer models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticDomain, make_workload
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine
from repro.serving.request import Request, RequestManager


def _tiny(arch, vocab=64, **kw):
    base = dict(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                head_dim=32, d_ff=128, vocab_size=vocab)
    base.update(kw)
    cfg = get_reduced(arch, **base)
    return cfg


def _prompts(n, vocab, lo=6, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [SyntheticDomain("alpaca", vocab, i).sample_prompt(rng)
            [: rng.integers(lo, hi)] for i in range(n)]


@pytest.fixture(scope="module")
def dense_pair():
    dm = Model(_tiny("olmo-1b"))
    tm = Model(_tiny("qwen3-8b", d_model=128, num_heads=4, d_ff=256))
    dp = dm.init(jax.random.PRNGKey(0))
    tp = tm.init(jax.random.PRNGKey(1))
    return dm, tm, dp, tp


class TestEngineBasics:
    def test_round_invariants(self, dense_pair):
        dm, tm, dp, tp = dense_pair
        n = 4
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=12, s_max=5, cache_len=128,
                              draft_temps=(1.0, 1.3, 0.8, 1.6))
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(n, 64), dp, tp,
                         rounds=6)
        for h in hist:
            assert h.S.sum() <= 12
            assert np.all(h.S <= 5)
            assert np.all(h.accepted <= h.S)
            assert np.all(h.realized == h.accepted + 1)
            assert np.all((h.alpha_hat > 0) & (h.alpha_hat < 1))
            assert np.isfinite(h.utility)
            assert h.wall[0] > 0
            # emitted rows: m real tokens then the extra token then -1 pad
            for i in range(n):
                row = h.emitted[i]
                m = h.accepted[i]
                assert np.all(row[:m + 1] >= 0)
                assert np.all(row[m + 1:] == -1)

    def test_identical_models_accept_all(self):
        """Losslessness smoke: draft == target => every draft accepted."""
        cfg = _tiny("qwen3-8b")
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        eng = GoodSpeedEngine(draft_model=m, target_model=m, n_servers=3,
                              C=9, s_max=4, cache_len=96)
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(3, 64), p, p,
                         rounds=6)
        for h in hist:
            np.testing.assert_array_equal(h.accepted, h.S)

    def test_cache_matches_fresh_prefill(self, dense_pair):
        """Cache-integrity: after rounds, the engine's next-step logits for
        the committed sequence equal a from-scratch prefill's logits."""
        dm, tm, dp, tp = dense_pair
        n = 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=96)
        prompts = _prompts(n, 64, seed=3)
        state = eng.init(jax.random.PRNGKey(2), prompts, dp, tp)
        committed = [list(p) for p in prompts]
        for _ in range(4):
            state, stats = eng.run_round(state, dp, tp)
            for i in range(n):
                row = stats.emitted[i]
                committed[i].extend(int(t) for t in row[row >= 0])
        # engine view: decode `pending` (last committed token) one step
        pos = state.length[:, None]
        out_eng = tm.forward(tp, state.pending[:, None], mode="decode",
                             cache=state.target_cache, positions=pos)
        # fresh view: full prefill of committed tokens
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            got = out_eng.logits[i, 0]
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 3e-3, f"row {i}: cache drift {err}"

    def test_recompute_path_archs(self):
        """Recurrent/hybrid/sliding targets exercise checkpoint-recompute."""
        for arch in ("xlstm-350m", "recurrentgemma-9b", "h2o-danube-3-4b"):
            tm = Model(_tiny(arch))
            dm = Model(_tiny("olmo-1b"))
            dp = dm.init(jax.random.PRNGKey(0))
            tp = tm.init(jax.random.PRNGKey(1))
            eng = GoodSpeedEngine(draft_model=dm, target_model=tm,
                                  n_servers=2, C=6, s_max=3, cache_len=64)
            hist = eng.serve(jax.random.PRNGKey(2), _prompts(2, 64), dp, tp,
                             rounds=4)
            assert all(np.isfinite(h.utility) for h in hist), arch

    def test_recompute_cache_integrity(self):
        """Cache-integrity under the recompute rollback (sliding window)."""
        tm = Model(_tiny("h2o-danube-3-4b", window=16))
        dm = Model(_tiny("olmo-1b"))
        dp = dm.init(jax.random.PRNGKey(0))
        tp = tm.init(jax.random.PRNGKey(1))
        n = 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=16)
        prompts = _prompts(n, 64, seed=5)
        state = eng.init(jax.random.PRNGKey(2), prompts, dp, tp)
        committed = [list(p) for p in prompts]
        for _ in range(3):
            state, stats = eng.run_round(state, dp, tp)
            for i in range(n):
                row = stats.emitted[i]
                committed[i].extend(int(t) for t in row[row >= 0])
        out_eng = tm.forward(tp, state.pending[:, None], mode="decode",
                             cache=state.target_cache,
                             positions=state.length[:, None])
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            err = float(jnp.max(jnp.abs(out_eng.logits[i, 0] - ref)))
            assert err < 3e-3, f"row {i}: recompute cache drift {err}"


class TestServeRequests:
    """Request-lifecycle serving loop: continuous batching over more
    requests than draft servers."""

    def _requests(self, k, vocab=64, max_new=5, seed=11):
        rng = np.random.default_rng(seed)
        return [Request(prompt=SyntheticDomain("alpaca", vocab, 50 + i)
                        .sample_prompt(rng)[:8], max_new_tokens=max_new)
                for i in range(k)]

    def test_drains_oversubscribed_workload(self, dense_pair):
        """7 requests on 2 servers: all complete, every request gets its
        full token budget, and latency/goodput stats are reported."""
        dm, tm, dp, tp = dense_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                              C=8, s_max=4, cache_len=128)
        rep = eng.serve_requests(jax.random.PRNGKey(0), self._requests(7),
                                 dp, tp, rounds=60)
        assert rep["summary"]["completed"] == 7
        assert rep["summary"]["queued"] == 0
        assert rep["summary"]["active"] == 0
        for r in rep["requests"]:
            assert r["tokens"] == 5
            assert r["finish_round"] > r["arrival_round"]
            assert r["latency_rounds"] >= 1
        # early admissions should not wait; later ones queue behind them
        delays = [r["queue_delay_rounds"] for r in rep["requests"]]
        assert min(delays) == 0 and max(delays) >= 1

    def test_idle_servers_get_zero_budget(self, dense_pair):
        """With a single 1-request workload on server 0, server 1 is idle:
        zero scheduler budget, nothing emitted, cache row untouched."""
        dm, tm, dp, tp = dense_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                              C=8, s_max=4, cache_len=128)
        req = self._requests(1, max_new=6)[0]
        rep = eng.serve_requests(jax.random.PRNGKey(1), [(0, 0, req)],
                                 dp, tp, rounds=40)
        assert rep["summary"]["completed"] == 1
        for h in rep["rounds"]:
            assert h.S[1] == 0
            assert h.realized[1] == 0
            assert np.all(h.emitted[1] == -1)

    def test_timed_arrivals_and_caches_consistent(self, dense_pair):
        """Staggered arrivals: fresh admissions re-prefill their rows
        mid-run and every row's cache stays equal to a from-scratch
        recompute of its committed sequence."""
        dm, tm, dp, tp = dense_pair
        n, vocab = 2, 64
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=6, s_max=3, cache_len=96)
        reqs = self._requests(5, max_new=4, seed=13)
        workload = [(j, j % n, r) for j, r in enumerate(reqs)]
        mgr = RequestManager(n)
        state = eng.init(jax.random.PRNGKey(2),
                         [np.zeros(1, np.int32)] * n, dp, tp)
        committed = [[0] for _ in range(n)]
        next_arr = 0
        for r in range(40):
            while next_arr < len(workload) and workload[next_arr][0] <= r:
                mgr.submit(workload[next_arr][1], workload[next_arr][2])
                next_arr += 1
            fresh = mgr.admit()
            if fresh:
                state = eng._admit_rows(
                    state, fresh, {i: mgr.active[i].prompt for i in fresh},
                    dp, tp)
                for i in fresh:
                    committed[i] = list(mgr.active[i].prompt)
            if mgr.idle() and next_arr >= len(workload):
                break
            caps = mgr.remaining_caps()
            state, stats = eng.run_round(state, dp, tp, caps=caps)
            mgr.record_emitted(stats.emitted)
            for i in range(n):
                if caps[i] > 0:
                    row = stats.emitted[i]
                    committed[i].extend(int(t) for t in row[row >= 0])
        mgr.admit()
        assert mgr.stats()["completed"] == 5
        out_eng = tm.forward(tp, state.pending[:, None], mode="decode",
                             cache=state.target_cache,
                             positions=state.length[:, None])
        for i in range(n):
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            err = float(jnp.max(jnp.abs(out_eng.logits[i, 0] - ref)))
            assert err < 3e-3, f"row {i}: cache drift {err}"

    def test_interrupted_drain_resumes_with_manager(self, dense_pair):
        """A rounds budget too small to drain: the post-loop step retires
        only (never seats a request no round will serve), and resuming
        with the same manager re-prefills mid-flight requests from
        prompt + generated-so-far and completes everything."""
        dm, tm, dp, tp = dense_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=1,
                              C=4, s_max=2, cache_len=128)
        reqs = self._requests(3, max_new=6, seed=17)
        mgr = RequestManager(1)
        rep1 = eng.serve_requests(jax.random.PRNGKey(4), reqs, dp, tp,
                                  rounds=2, manager=mgr)
        s1 = rep1["summary"]
        assert s1["completed"] < 3
        # an unfinished in-flight request may remain active; none of the
        # queued ones may have been seated post-loop with zero rounds left
        for req in mgr.active:
            assert req is None or not req.done
        mid = [r for r in mgr.active if r is not None]
        rep2 = eng.serve_requests(jax.random.PRNGKey(5), [], dp, tp,
                                  rounds=60, manager=mgr)
        assert rep2["summary"]["completed"] == 3          # manager lifetime
        # per-call records/throughput cover only this call's completions
        assert rep2["summary"]["completed_this_call"] == len(rep2["requests"])
        assert rep1["summary"]["completed_this_call"] \
            + rep2["summary"]["completed_this_call"] == 3
        for r in rep2["requests"]:
            assert r["tokens"] == 6
        # the resumed request kept its pre-interruption tokens
        if mid:
            done = next(r for r in rep2["requests"]
                        if r["request_id"] == mid[0].request_id)
            assert done["tokens"] == 6

    def test_arrival_gap_ticks_without_rounds(self, dense_pair):
        """A gap before a late arrival must not burn model rounds: the
        clock ticks, rounds_run counts only executed rounds, and latency
        still measures from arrival."""
        dm, tm, dp, tp = dense_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                              C=8, s_max=4, cache_len=128)
        req = self._requests(1, max_new=4, seed=19)[0]
        rep = eng.serve_requests(jax.random.PRNGKey(6), [(10, 0, req)],
                                 dp, tp, rounds=40)
        s = rep["summary"]
        assert s["completed"] == 1
        assert s["unsubmitted"] == 0
        r = rep["requests"][0]
        assert r["arrival_round"] == 10 and r["admit_round"] == 10
        # rounds 0..9 were idle ticks, not executed engine rounds
        assert s["rounds_run"] <= 6
        # an arrival past the budget is counted, not silently dropped
        late = self._requests(1, max_new=4, seed=23)[0]
        rep2 = eng.serve_requests(jax.random.PRNGKey(7), [(100, 0, late)],
                                  dp, tp, rounds=20)
        assert rep2["summary"]["completed"] == 0
        assert rep2["summary"]["unsubmitted"] == 1

    def test_eos_stops_generation(self):
        """Draft == target with a forced-EOS vocab distribution: requests
        finish on EOS before their cap and generated text stops at EOS."""
        cfg = _tiny("qwen3-8b", vocab=16)
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        eng = GoodSpeedEngine(draft_model=m, target_model=m, n_servers=2,
                              C=6, s_max=3, cache_len=128)
        reqs = [Request(prompt=np.asarray([1, 2, 3], np.int32),
                        max_new_tokens=40, eos_token=e)
                for e in (4, 7, 4, 7)]
        rep = eng.serve_requests(jax.random.PRNGKey(3), reqs, p, p,
                                 rounds=80)
        assert rep["summary"]["completed"] == 4
        eos_of = {q.request_id: q.eos_token for q in reqs}
        hit = 0
        for r in rep["requests"]:
            g = r["generated"]
            eos = eos_of[r["request_id"]]
            if eos in g:
                hit += 1
                assert g.index(eos) == len(g) - 1, g
                assert r["tokens"] < 40            # finished early on EOS
        assert hit > 0   # a 16-token vocab must hit EOS within 40 draws


class TestEngineScheduling:
    def test_goodspeed_shifts_budget_to_high_alpha(self):
        """With a shared draft model but very different temperatures, the
        cold-temperature (well-aligned) servers should end up with larger
        allocations under the goodspeed policy."""
        cfg = _tiny("qwen3-8b")
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        n = 4
        # temp 1.0 == target distribution (alpha ~ 1), temp 3.0 mismatched
        eng = GoodSpeedEngine(draft_model=m, target_model=m, n_servers=n,
                              C=16, s_max=8, cache_len=256,
                              draft_temps=(1.0, 1.0, 3.0, 3.0),
                              policy="goodspeed")
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(n, 64), p, p,
                         rounds=12)
        tail = np.mean([h.S for h in hist[-4:]], axis=0)
        assert tail[:2].mean() > tail[2:].mean(), tail
        ah = hist[-1].alpha_hat
        assert ah[:2].mean() > ah[2:].mean(), ah
