"""Draft-lane tests (ISSUE 5): multi-request rows per draft server.

Covers the three layers of the lane refactor:

  * ``core.scheduler.split_lanes`` — the per-server water-filling lane
    splitter (conservation, caps, evenness, determinism, idle lanes);
  * ``core.estimator`` — the Eq. 4 goodput EMA holds for UNOBSERVED
    servers exactly like alpha_hat (the idle-weight-drag bugfix): an
    idle-then-readmitted server re-enters the scheduler with the same
    fairness weight it left with;
  * ``serving.request.RequestManager(lanes=R)`` — lane conservation: a
    request is never seated on two lanes, rows are server-major, per-lane
    retirement frees exactly one lane;
  * ``serving.engine.GoodSpeedEngine(lanes=R)`` — lanes=1 emits
    byte-identical accepted-token sequences to the recorded pre-lane
    (PR-4) engine on the ACCEPTANCE mixed admit/retire/EOS trace for
    paged x static caches x jnp x kernel backends
    (``tests/data/mixed_trace_golden.json``; regenerate by running the
    trace through ``conftest.mixed_trace`` and dumping
    ``generated_seqs``), per-lane caps are honored, lanes stay
    block-diagonal-independent (per-row cache == fresh prefill), and
    retiring one lane frees exactly that lane's paged blocks.

``make lanes-check`` runs this module standalone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.core.estimator import GoodputEstimator
from repro.core.scheduler import split_lanes
from repro.core.utility import UtilitySpec
from repro.serving.request import Request, RequestManager
from tests.proptest import sweep

GOLDEN = conftest.__file__.replace("conftest.py",
                                   "tests/data/mixed_trace_golden.json")


# ---------------------------------------------------------------------------
# split_lanes
# ---------------------------------------------------------------------------

class TestSplitLanes:
    def _check(self, S, caps, out, level_max):
        S, caps, out = np.asarray(S), np.asarray(caps), np.asarray(out)
        assert np.all(out >= 0)
        assert np.all(out <= caps), (S, caps, out)
        np.testing.assert_array_equal(
            out.sum(axis=1), np.minimum(S, caps.sum(axis=1)))
        # water level: two lanes differ by more than 1 only when the
        # smaller one is pinned at its cap
        for i in range(out.shape[0]):
            for r in range(out.shape[1]):
                for q in range(out.shape[1]):
                    if out[i, r] > out[i, q] + 1:
                        assert out[i, q] == caps[i, q], (S[i], caps[i], out[i])

    @sweep(cases=40, seed=21)
    def test_properties_random(self, draw):
        n = draw.integers(1, 5)
        lanes = draw.integers(1, 5)
        level_max = draw.integers(1, 8)
        caps = draw.int_array((n, lanes), 0, level_max)
        S = draw.int_array((n,), 0, lanes * level_max + 3)
        out = split_lanes(jnp.asarray(S, jnp.int32),
                          jnp.asarray(caps, jnp.int32), level_max)
        self._check(S, caps, out, level_max)

    def test_even_split_and_remainder_order(self):
        out = np.asarray(split_lanes(jnp.asarray([7], jnp.int32),
                                     jnp.asarray([[4, 4, 4]], jnp.int32), 4))
        # water-filled: 3/2/2 with the remainder on the lowest lane
        np.testing.assert_array_equal(out, [[3, 2, 2]])

    def test_idle_lanes_get_nothing(self):
        out = np.asarray(split_lanes(jnp.asarray([5], jnp.int32),
                                     jnp.asarray([[3, 0, 4]], jnp.int32), 4))
        assert out[0, 1] == 0
        assert out.sum() == 5

    def test_capped_lane_overflows_to_others(self):
        out = np.asarray(split_lanes(jnp.asarray([6], jnp.int32),
                                     jnp.asarray([[1, 4, 4]], jnp.int32), 4))
        np.testing.assert_array_equal(out, [[1, 3, 2]])

    def test_lanes_one_is_identity(self):
        S = jnp.asarray([0, 2, 5], jnp.int32)
        caps = jnp.asarray([[0], [3], [4]], jnp.int32)
        out = np.asarray(split_lanes(S, caps, 5))
        np.testing.assert_array_equal(out[:, 0], [0, 2, 4])


# ---------------------------------------------------------------------------
# estimator: unobserved servers hold BOTH estimates (Eq. 4 bugfix)
# ---------------------------------------------------------------------------

class TestGoodputHoldsUnobserved:
    def test_idle_rounds_do_not_drag_weight(self):
        """An idle server's fairness weight w = dU/dx(X^beta) must be
        unchanged by rounds it never drafted in — before the fix the
        goodput EMA updated unconditionally and dragged X toward the
        realized x of rounds the server did not participate in."""
        est = GoodputEstimator()
        util = UtilitySpec(alpha=1.0)
        st = est.init(3)
        # one observed round for everyone: estimates diverge from init
        st = est.update(st, jnp.asarray([1.5, 0.8, 0.2]),
                        jnp.asarray([2, 2, 2], jnp.int32),
                        jnp.asarray([3.0, 2.0, 1.0]))
        w_before = np.asarray(util.grad(st.goodput))
        a_before = np.asarray(st.alpha_hat)
        # five rounds with server 1 idle (S = 0, nothing realized)
        for _ in range(5):
            st = est.update(st, jnp.asarray([1.2, 0.0, 0.3]),
                            jnp.asarray([2, 0, 2], jnp.int32),
                            jnp.asarray([3.0, 0.0, 2.0]))
        w_after = np.asarray(util.grad(st.goodput))
        assert w_after[1] == w_before[1], (w_before, w_after)
        assert np.asarray(st.alpha_hat)[1] == a_before[1]
        # the observed servers DID move
        assert w_after[0] != w_before[0]
        assert w_after[2] != w_before[2]

    def test_zero_s_active_round_holds_goodput(self):
        """Even a server that emitted a bonus token but was scheduled
        S_i = 0 contributes no Eq. 3/4 observation (satellite: same
        ``jnp.where(observed, ...)`` guard as alpha_hat)."""
        est = GoodputEstimator()
        st = est.init(2)
        st2 = est.update(st, jnp.asarray([0.0, 1.0]),
                         jnp.asarray([0, 2], jnp.int32),
                         jnp.asarray([1.0, 3.0]))
        assert float(st2.goodput[0]) == float(st.goodput[0])
        assert float(st2.alpha_hat[0]) == float(st.alpha_hat[0])
        assert float(st2.goodput[1]) != float(st.goodput[1])


# ---------------------------------------------------------------------------
# latency model: lanes share their server's uplink
# ---------------------------------------------------------------------------

class TestLatencyLanes:
    def test_lanes_share_server_uplink(self):
        """A server's lanes decode in one batched forward (draft time =
        slowest lane) but SHARE the uplink: grouping 4 equal rows onto
        one server must cost more receive time than 4 independent
        servers (payloads sum over the shared link), and exactly the
        single-server cost of the summed payload."""
        from repro.core.latency import LatencyModel
        lm = LatencyModel()
        S = jnp.full((4,), 6, jnp.int32)
        jit0 = jnp.zeros((4,))
        as_servers = lm.receive_time(S, 256, jit0)
        as_lanes = lm.receive_time(S, 256, jit0, lanes=4)
        one_link = lm.receive_time(jnp.asarray([24], jnp.int32), 256,
                                   jnp.zeros((1,)))
        assert float(as_lanes) > float(as_servers)
        # draft time differs (sequential 24 vs batched max 6); compare
        # the uplink component: total = draft(6) + payload(24)/link + rtt
        expect = float(lm.draft_time(S, jit0)[0]) \
            + float(one_link) - float(lm.draft_time(
                jnp.asarray([24], jnp.int32), jnp.zeros((1,)))[0])
        np.testing.assert_allclose(float(as_lanes), expect, rtol=1e-6)

    def test_lanes_one_is_passthrough(self):
        from repro.core.latency import LatencyModel
        lm = LatencyModel()
        S = jnp.asarray([3, 0, 5], jnp.int32)
        jit = jnp.asarray([0.2, -0.4, 0.9])
        a = lm.round_time(S, S, 256, jit)
        b = lm.round_time(S, S, 256, jit, lanes=1)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


# ---------------------------------------------------------------------------
# RequestManager lanes: conservation + seating invariants (model-free)
# ---------------------------------------------------------------------------

EMIT_W = 4


def _emitted_row(r, i):
    cnt = (r * 31 + i * 7) % 3 + 1
    return [((r + i + j) % 5 + 1) for j in range(cnt)] \
        + [-1] * (EMIT_W - cnt)


def _drive_lanes(mgr, trace, rounds):
    """test_placement's model-free driver generalized to lane rows."""
    reqs = [Request(prompt=np.zeros(pl, np.int32), max_new_tokens=mn,
                    eos_token=eos) for (_, _, pl, mn, eos) in trace]
    idx = 0
    for r in range(rounds):
        while idx < len(trace) and trace[idx][0] <= r:
            mgr.submit(trace[idx][1], reqs[idx])
            idx += 1
        mgr.admit()
        # invariant: a request occupies at most ONE lane row, on the
        # server the policy placed it on (server-major rows)
        live = [q for q in mgr.active if q is not None]
        ids = [q.request_id for q in live]
        assert len(ids) == len(set(ids)), "request seated on two lanes"
        for row, q in enumerate(mgr.active):
            if q is not None:
                assert q.placed_server == mgr.server_of(row)
                assert q.placed_lane == row % mgr.lanes
        caps = mgr.remaining_caps()
        assert caps.shape == (mgr.rows,)
        if caps.any():
            emitted = np.asarray(
                [_emitted_row(r, i) if caps[i] > 0 else [-1] * EMIT_W
                 for i in range(mgr.rows)], np.int32)
            mgr.record_emitted(emitted)
        else:
            mgr.tick()
    mgr.retire_done()
    return reqs


class TestLaneManager:
    @sweep(cases=20, seed=70)
    def test_conservation_and_single_seat(self, draw):
        n = draw.integers(1, 3)
        lanes = draw.integers(2, 4)
        k = draw.integers(3, 14)
        trace = [(draw.integers(0, 8), draw.integers(0, n - 1),
                  draw.integers(1, 6), draw.integers(1, 6),
                  3 if j % 3 == 0 else -1) for j in range(k)]
        trace.sort(key=lambda t: t[0])
        for policy in ("static", "jsq", "goodput"):
            mgr = RequestManager(n, placement=policy, lanes=lanes)
            reqs = _drive_lanes(mgr, trace, rounds=40)
            assert sorted(q.request_id for q in mgr.completed) \
                == sorted(q.request_id for q in reqs), policy

    def test_multi_lane_seats_same_server(self):
        """Two lanes on one server seat two requests at once; retiring
        one frees exactly that lane and the successor lands in it."""
        mgr = RequestManager(1, lanes=2)
        a = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        b = Request(prompt=np.zeros(2, np.int32), max_new_tokens=6)
        c = Request(prompt=np.zeros(2, np.int32), max_new_tokens=3)
        for q in (a, b, c):
            mgr.submit(0, q)
        assert mgr.admit() == [0, 1]
        assert mgr.active[0] is a and mgr.active[1] is b
        assert (a.placed_lane, b.placed_lane) == (0, 1)
        np.testing.assert_array_equal(mgr.remaining_caps(), [2, 6])
        # finish a (lane 0) only
        mgr.record_emitted(np.asarray([[5, 5, -1], [5, -1, -1]], np.int32))
        assert mgr.admit() == [0]          # c takes the freed lane 0
        assert mgr.active[0] is c and mgr.active[1] is b
        np.testing.assert_array_equal(mgr.remaining_caps(), [3, 5])

    def test_server_remaining_aggregates_lanes(self):
        mgr = RequestManager(2, lanes=2)
        mgr.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=4))
        mgr.submit(0, Request(prompt=np.zeros(2, np.int32), max_new_tokens=3))
        mgr.submit(1, Request(prompt=np.zeros(2, np.int32), max_new_tokens=5))
        mgr.admit()
        np.testing.assert_array_equal(mgr.remaining_caps(), [4, 3, 5, 0])
        np.testing.assert_array_equal(mgr.server_remaining(), [7, 5])

    def test_lanes_one_backward_compatible(self):
        mgr = RequestManager(2)
        assert mgr.lanes == 1 and mgr.rows == 2
        mgr.submit(0, Request(prompt=np.zeros(2, np.int32),
                              max_new_tokens=2))
        assert mgr.admit() == [0]
        assert mgr.active[0].placed_lane == 0


# ---------------------------------------------------------------------------
# engine-level pins
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestLanesOneEquivalenceTrace:
    """``GoodSpeedEngine(lanes=1)`` must be byte-identical to the PRE-LANE
    (PR-4) engine: accepted-token sequences on the ACCEPTANCE mixed
    admit/retire/EOS trace, pinned against the recorded golden, across
    paged x static caches and jnp x kernel backends."""

    @pytest.mark.parametrize("paged,backend", [
        (False, "jnp"), (True, "jnp"), (False, "kernel"), (True, "kernel")])
    def test_lanes1_matches_recorded_pr4_trace(self, mixed_trace, paged,
                                               backend):
        import json
        golden = json.load(open(GOLDEN))
        rep = mixed_trace(lanes=1, paged_kv=paged, attn_backend=backend)
        assert conftest.generated_seqs(rep) == golden


@pytest.mark.slow
class TestLanesEngine:
    def test_lanes2_drains_mixed_trace(self, mixed_trace):
        """The ACCEPTANCE trace drains under lanes=2 (static and paged),
        every request reports its lane, and no lane row ever exceeds the
        per-lane draft cap."""
        for paged in (False, True):
            rep = mixed_trace(lanes=2, paged_kv=paged)
            assert rep["summary"]["completed"] == 7
            for r in rep["requests"]:
                assert r["lane"] in (0, 1)
                assert r["server"] in (0, 1)
            for h in rep["rounds"]:
                assert h.S.shape == (4,)           # 2 servers x 2 lanes
                assert np.all(h.S <= 4)            # s_max per lane
                assert h.S.sum() <= 8              # C
                assert h.alpha_hat.shape == (2,)   # per-server fairness

    def test_lane_rows_block_diagonal_consistent(self, serve_pair):
        """Per-lane cache integrity: drive a lanes=2 engine manually and
        check every row's next-step target logits equal a from-scratch
        prefill of that row's committed sequence — lanes never leak into
        each other's attention."""
        from repro.serving.engine import GoodSpeedEngine
        dm, tm, dp, tp = serve_pair
        n, lanes = 2, 2
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=n,
                              C=8, s_max=3, cache_len=128, lanes=lanes)
        rng = np.random.default_rng(5)
        reqs = [Request(prompt=rng.integers(
            1, conftest.MIXED_TRACE_VOCAB, size=6).astype(np.int32),
            max_new_tokens=5) for _ in range(6)]
        mgr = RequestManager(n, lanes=lanes)
        state = eng.cold_start(jax.random.PRNGKey(3))
        committed = [None] * (n * lanes)
        for j, q in enumerate(reqs):
            mgr.submit(j % n, q)
        for _ in range(30):
            fresh = mgr.admit()
            if fresh:
                state = eng._admit_rows(
                    state, fresh, {i: mgr.active[i].prompt for i in fresh},
                    dp, tp)
                for i in fresh:
                    committed[i] = list(mgr.active[i].prompt)
            if mgr.idle():
                break
            caps = mgr.remaining_caps()
            state, stats = eng.run_round(state, dp, tp, caps=caps)
            assert np.all(stats.S <= np.minimum(caps, 3))   # per-lane caps
            mgr.record_emitted(stats.emitted)
            for i in range(n * lanes):
                if caps[i] > 0:
                    row = stats.emitted[i]
                    committed[i].extend(int(t) for t in row[row >= 0])
        mgr.retire_done()
        assert mgr.stats()["completed"] == 6
        out = tm.forward(tp, state.pending[:, None], mode="decode",
                         cache=state.target_cache,
                         positions=state.length[:, None])
        for i in range(n * lanes):
            if committed[i] is None:
                continue
            toks = jnp.asarray(committed[i], jnp.int32)[None, :]
            ref = tm.forward(tp, toks, mode="train").logits[0, -1]
            err = float(jnp.max(jnp.abs(out.logits[i, 0] - ref)))
            assert err < 3e-3, f"row {i}: lane cache drift {err}"

    def test_lane_retirement_frees_exactly_that_lanes_blocks(self,
                                                             serve_pair):
        """Paged accounting per lane: releasing one lane's row returns
        exactly that lane's blocks to the pool and leaves the sibling
        lane's block table untouched."""
        from repro.serving.engine import GoodSpeedEngine, \
            _first_paged_leaf, _paged_alloc_state
        dm, tm, dp, tp = serve_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=1,
                              C=8, s_max=4, cache_len=128, lanes=2,
                              paged_kv=True, kv_block_size=8)
        state = eng.cold_start(jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        p0 = rng.integers(1, 64, size=17).astype(np.int32)   # feeds 16 = 2 blk
        p1 = rng.integers(1, 64, size=9).astype(np.int32)    # feeds 8 = 1 blk
        state = eng._admit_rows(state, [0, 1], {0: p0, 1: p1}, dp, tp)
        free0 = int(np.asarray(
            _paged_alloc_state(state.target_cache)[1]).sum())
        table_before = np.asarray(_first_paged_leaf(state.target_cache).table)
        assert np.all(table_before[0, :2] >= 0)    # lane 0: 2 blocks
        assert table_before[1, 0] >= 0             # lane 1: 1 block
        state = eng._release_rows(state, [0])
        leaf = _first_paged_leaf(state.target_cache)
        free1 = int(np.asarray(_paged_alloc_state(
            state.target_cache)[1]).sum())
        assert free1 - free0 == 2                  # exactly lane 0's blocks
        assert np.all(np.asarray(leaf.table)[0] == -1)
        np.testing.assert_array_equal(np.asarray(leaf.table)[1],
                                      table_before[1])
