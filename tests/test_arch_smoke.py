"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2+ layers, d_model<=128, <=4 experts) and run one forward pass AND one
train step on CPU, asserting output shapes and finiteness.  The FULL
configs are exercised only via the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, get_reduced
from repro.launch.specs import batch_specs
from repro.models import Model
from repro.training.optimizer import AdamW
from repro.training.train_state import init_train_state, make_train_step

ARCHS = sorted(ARCHITECTURES)


def _batch_for(cfg, b=2, s=16, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(keys[0], (b, s), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jax.random.normal(
            keys[1], (b, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jax.random.normal(
            keys[2], (b, cfg.encoder.source_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    kwargs = {}
    if "prefix_embeds" in batch:
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    if "audio_embeds" in batch:
        kwargs["enc_out"] = model.encode(params, batch["audio_embeds"])
    out = model.forward(params, batch["tokens"], mode="train", **kwargs)
    expect_s = batch["tokens"].shape[1] + (cfg.num_prefix_embeds
                                           if cfg.frontend == "vision" else 0)
    assert out.logits.shape == (2, expect_s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(out.logits))), f"{arch}: NaN/Inf logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    model = Model(cfg)
    opt = AdamW(learning_rate=1e-3, warmup_steps=0, total_steps=10,
                schedule="constant")
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, remat=False))
    batch = _batch_for(cfg)
    state1, metrics = step(state, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0), f"{arch}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero grads"
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, ab: acc + float(jnp.sum(jnp.abs(ab))),
        jax.tree.map(lambda a, b: jnp.mean(jnp.abs(a - b)),
                     state.params, state1.params), 0.0)
    assert moved > 0, f"{arch}: params unchanged"
    # loss goes down over a few steps on a repeated batch (memorization)
    s = state1
    for _ in range(8):
        s, m = step(s, batch)
    assert float(m["loss"]) < loss0, \
        f"{arch}: loss did not decrease ({loss0} -> {float(m['loss'])})"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    """One serve_step against a small cache: shapes + finiteness."""
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    specs = batch_specs(cfg, "decode_32k", concrete=True, batch=2,
                        seq=32, cache_len=32)
    kwargs = {}
    if "audio_embeds" in specs:
        kwargs["enc_out"] = model.encode(params, specs["audio_embeds"])
    out = model.forward(params, specs["tokens"], mode="decode",
                        cache=specs["cache"], positions=specs["positions"],
                        **kwargs)
    assert out.logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(out.logits)))
    assert out.cache is not None


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for name, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(name)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (nl, d, h, kv, ff, v), (name, got)
        assert cfg.source, f"{name}: missing citation"
    moe = get_config("qwen3-moe-235b-a22b").moe
    assert (moe.num_experts, moe.top_k) == (128, 8)
    ds = get_config("deepseek-v2-lite-16b")
    assert (ds.moe.num_experts, ds.moe.top_k,
            ds.moe.num_shared_experts) == (64, 6, 2)
    assert ds.mla.kv_lora_rank == 512
    assert get_config("recurrentgemma-9b").block_pattern == \
        ("rglru", "rglru", "local_attn")


def test_param_counts_plausible():
    """Approximate parameter counts are in the right ballpark."""
    expect = {"qwen3-8b": 8e9, "stablelm-12b": 12e9, "olmo-1b": 1.2e9,
              "h2o-danube-3-4b": 4e9, "qwen3-moe-235b-a22b": 235e9,
              "deepseek-v2-lite-16b": 16e9, "recurrentgemma-9b": 9e9,
              "xlstm-350m": 0.35e9}
    for name, target in expect.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 1.9 * target, (name, n, target)
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.15 * moe.param_count()
