"""Runtime jit-discipline guards (serving.guards): the retrace budget
and the transfer fence — the dynamic complement of the jaxlint static
rules (docs/STATIC_ANALYSIS.md).

Retrace budget: the mixed admit/retire/EOS drain compiles each round
phase AT MOST ONCE per bucket shape, across sync x overlap and jnp x
kernel backends — previously a benchmark-only assertion
(benchmarks/serve_requests.py), promoted here to tier-1 via
``serve_requests(strict_compile=...)``.

Transfer fence: ``jax.transfer_guard("disallow")`` around
``dispatch_round`` proves a steady-state round performs NO implicit
host->device transfers — every host input (caps, fault arrays) is
explicitly converted (``jnp.asarray``) before dispatch.  Host work
deliberately OUTSIDE the fence, by design:

  * ``run_round``'s RoundStats materialization (``np.asarray`` of the
    raw device tuple) — the round's one sanctioned sync point;
  * placement views / admission prefill (``_placement_view``,
    ``_admit_rows``) — between-round orchestration on host buffers;
  * fault-plan compilation (``FaultPlan.round_faults`` builds numpy
    arrays; ``dispatch_round`` lifts them explicitly);
  * pool-health checks (``_check_pool_health`` reads the small
    allocator fields after the round returns).
"""
import jax
import numpy as np
import pytest

from conftest import mixed_trace_requests
from repro.serving.engine import GoodSpeedEngine
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.guards import RetraceError, TraceGuard


def make_engine(serve_pair, **kw):
    dm, tm, dp, tp = serve_pair
    base = dict(draft_model=dm, target_model=tm, n_servers=2, C=8,
                s_max=4, cache_len=128, kv_block_size=16)
    base.update(kw)
    return GoodSpeedEngine(**base), dp, tp


# ---------------------------------------------------------------------------
# retrace budget: one compile per phase per bucket, enforced in-loop
# ---------------------------------------------------------------------------

class TestRetraceBudget:
    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["sync", "overlap"])
    def test_mixed_drain_compiles_once(self, serve_pair, overlap):
        """The acceptance drain (admits, cap/EOS retirements, queued
        successors, idle tail) holds the one-compile-per-phase budget
        end to end — any shape drift in the round inputs would raise
        RetraceError at the offending round."""
        eng, dp, tp = make_engine(serve_pair, overlap=overlap)
        rep = eng.serve_requests(jax.random.PRNGKey(0),
                                 mixed_trace_requests(7), dp, tp,
                                 rounds=60, strict_compile=True)
        assert rep["summary"]["completed"] == 7
        counts = eng.round_trace_counts()
        assert counts and all(v == 1 for v in counts.values()), counts

    @pytest.mark.slow
    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["sync", "overlap"])
    def test_mixed_drain_compiles_once_kernel(self, serve_pair, overlap):
        """Same budget through the Pallas kernel round (paged caches +
        flash/paged-flash attention)."""
        eng, dp, tp = make_engine(serve_pair, overlap=overlap,
                                  paged_kv=True, attn_backend="kernel")
        rep = eng.serve_requests(jax.random.PRNGKey(0),
                                 mixed_trace_requests(7), dp, tp,
                                 rounds=60, strict_compile=True)
        assert rep["summary"]["completed"] == 7
        counts = eng.round_trace_counts()
        assert counts and all(v == 1 for v in counts.values()), counts

    def test_prewarmed_drain_holds_zero_budget(self, serve_pair):
        """After one drain, a second identical-bucket drain on the SAME
        engine must not compile anything: strict_compile=0 (a valid
        budget, distinct from False=off) enforces it per round."""
        eng, dp, tp = make_engine(serve_pair)
        eng.serve_requests(jax.random.PRNGKey(0), mixed_trace_requests(3),
                           dp, tp, rounds=40)
        rep = eng.serve_requests(jax.random.PRNGKey(1),
                                 mixed_trace_requests(3), dp, tp,
                                 rounds=40, strict_compile=0)
        assert rep["summary"]["completed"] == 3

    def test_cold_engine_trips_zero_budget(self, serve_pair):
        """The guard actually fires through serve_requests: a cold
        engine's first compile exceeds budget 0 and the error names the
        phase and round."""
        eng, dp, tp = make_engine(serve_pair)
        with pytest.raises(RetraceError, match=r"round 0.*round:.*budget"):
            eng.serve_requests(jax.random.PRNGKey(0),
                               mixed_trace_requests(3), dp, tp,
                               rounds=10, strict_compile=0)

    def test_trace_guard_context_manager(self, serve_pair):
        """Direct TraceGuard use: budget 0 around a cold run_round
        raises on __exit__; budget 1 passes and check() returns the
        counts."""
        eng, dp, tp = make_engine(serve_pair, C=6, s_max=3, cache_len=64)
        prompts = [np.arange(1, 6, dtype=np.int32)] * eng.n_rows
        state = eng.init(jax.random.PRNGKey(0), prompts, dp, tp)
        with pytest.raises(RetraceError, match="round-phase retrace"):
            with TraceGuard(eng, budget=0):
                state, _ = eng.run_round(state, dp, tp)
        # warm now; a fresh zero-budget guard over more fixed-shape
        # rounds is clean, and varying cap VALUES must not retrace
        with TraceGuard(eng, budget=0) as guard:
            for r in range(3):
                caps = np.asarray([3, 2 + (r % 2)], np.int32)
                state, _ = eng.run_round(state, dp, tp, caps=caps)
            counts = guard.check("after 3 rounds")
        assert all(v == 1 for v in counts.values()), counts

    def test_faulted_drain_within_default_budget(self, serve_pair):
        """A fault plan routes every round through the traced-faults
        graph; strict_compile=True widens the budget to 2 and the drain
        stays within it."""
        eng, dp, tp = make_engine(serve_pair)
        plan = FaultPlan(events=(
            FaultEvent(round=1, kind="slowdown", server=0, factor=3.0,
                       duration=2),))
        rep = eng.serve_requests(jax.random.PRNGKey(0),
                                 mixed_trace_requests(3), dp, tp,
                                 rounds=40, faults=plan,
                                 strict_compile=True)
        assert rep["summary"]["completed"] == 3
        assert all(v <= 2 for v in eng.round_trace_counts().values())


# ---------------------------------------------------------------------------
# transfer fence: no implicit transfers in the dispatch path
# ---------------------------------------------------------------------------

class TestTransferFence:
    def test_fence_fires_on_this_backend(self):
        """Meta-test guarding against a vacuous pass: an implicit
        host->device transfer (raw numpy argument into a warm jit) must
        raise under the fence on this backend."""
        f = jax.jit(lambda x: x * 2)
        xn = np.arange(8, dtype=np.int32)
        f(xn)                                      # warm outside
        with pytest.raises(Exception, match="isallowed host-to-device"):
            with jax.transfer_guard("disallow"):
                f(xn)

    @pytest.mark.parametrize("overlap", [False, True],
                             ids=["sync", "overlap"])
    def test_steady_state_dispatch_is_transfer_clean(self, serve_pair,
                                                     overlap):
        """Steady-state rounds dispatch with zero implicit transfers:
        after warmup, dispatch_round runs under
        ``jax.transfer_guard("disallow")`` — host caps enter via the
        explicit ``jnp.asarray`` and every other operand is already a
        device buffer (the donated state round-trips on device).  The
        stats materialization stays outside the fence (the sanctioned
        sync point; see module docstring for the full outside-by-design
        list)."""
        eng, dp, tp = make_engine(serve_pair, overlap=overlap, C=6,
                                  s_max=3, cache_len=64)
        prompts = [np.arange(1, 6, dtype=np.int32)] * eng.n_rows
        state = eng.init(jax.random.PRNGKey(0), prompts, dp, tp)
        state, _ = eng.run_round(state, dp, tp)    # warmup + first sync
        with jax.transfer_guard("disallow"):
            for r in range(3):
                caps = np.asarray([3, 2 + (r % 2)], np.int32)
                state, raw, ahead = eng.dispatch_round(state, dp, tp,
                                                       caps=caps)
        # materialize OUTSIDE the fence; the round loop stayed healthy
        state, stats = eng.run_round(state, dp, tp)
        assert stats.S.shape == (eng.n_rows,)
        assert all(v == 1 for v in eng.round_trace_counts().values())

    def test_faulted_dispatch_is_transfer_clean(self, serve_pair):
        """Fault arrays are host numpy (FaultPlan.round_faults); the
        dispatch lifts them explicitly, so a faulted round is as
        transfer-clean as a nominal one."""
        eng, dp, tp = make_engine(serve_pair, C=6, s_max=3, cache_len=64)
        plan = FaultPlan(events=(
            FaultEvent(round=0, kind="slowdown", server=0, factor=2.0,
                       duration=8),))
        prompts = [np.arange(1, 6, dtype=np.int32)] * eng.n_rows
        state = eng.init(jax.random.PRNGKey(0), prompts, dp, tp)
        state, _ = eng.run_round(state, dp, tp,
                                 faults=plan.round_faults(0, eng.n_servers))
        with jax.transfer_guard("disallow"):
            for r in range(1, 3):
                rf = plan.round_faults(r, eng.n_servers)
                state, raw, ahead = eng.dispatch_round(state, dp, tp,
                                                       faults=rf)
        state, stats = eng.run_round(
            state, dp, tp, faults=plan.round_faults(3, eng.n_servers))
        assert stats.S.shape == (eng.n_rows,)
