"""Coordinator round-loop details: completion caps, logits path, latency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.core.speculative import verify


class TestCompletionCaps:
    def test_goodspeed_respects_remaining(self):
        """With max_new_tokens, the allocation never exceeds a request's
        remaining length (Fixed-S can; the paper's wasted-verification
        mechanism)."""
        coord = Coordinator(n=4, C=16, policy="goodspeed", max_new_tokens=5,
                            estimator=GoodputEstimator(
                                eta=StepSchedule(0.3),
                                beta=StepSchedule(0.2)))
        traj = jnp.full((60, 4), 0.8, jnp.float32)
        state, logs = coord.simulate_analytic(jax.random.PRNGKey(0), traj)
        S = np.asarray(logs.S)[1:]  # first round is the uniform warm start
        assert np.all(S <= 5), S.max()

    def test_realized_capped_and_reset(self):
        coord = Coordinator(n=2, C=8, policy="fixed", max_new_tokens=3)
        traj = jnp.full((40, 2), 0.95, jnp.float32)
        _, logs = coord.simulate_analytic(jax.random.PRNGKey(1), traj)
        realized = np.asarray(logs.realized)
        assert realized.max() <= 3.0 + 1e-6  # never beyond remaining

    def test_disabled_when_zero(self):
        coord = Coordinator(n=2, C=8, policy="goodspeed", max_new_tokens=0)
        traj = jnp.full((20, 2), 0.9, jnp.float32)
        _, logs = coord.simulate_analytic(jax.random.PRNGKey(2), traj)
        assert np.asarray(logs.realized).max() > 3.0  # uncapped geometric


class TestLogitsRound:
    def test_run_round_logits_consistency(self):
        """The faithful logits path: Eq.3 uses actual min(1,p/q) sums and
        the allocation stays within budget."""
        n, s_max, v = 3, 4, 32
        coord = Coordinator(n=n, C=8, policy="goodspeed")
        state = coord.init(jax.random.PRNGKey(0))
        state = state._replace(S=jnp.asarray([3, 3, 2], jnp.int32))
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(n, s_max, v)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(n, s_max + 1, v)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, v, size=(n, s_max)), jnp.int32)
        new_state, log, res = coord.run_round_logits(state, toks, q, p)
        assert int(jnp.sum(new_state.S)) <= 8
        np.testing.assert_array_equal(np.asarray(log.realized),
                                      np.asarray(res.num_emitted))
        # estimator consumed the true indicator sums
        assert bool(jnp.all(new_state.est.alpha_hat > 0))
        assert bool(jnp.all(new_state.est.alpha_hat < 1))
