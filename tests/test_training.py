"""Training substrate: AdamW, loss, checkpointing, end-to-end memorization."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import token_stream
from repro.models import Model
from repro.training import checkpoint
from repro.training.loss import cross_entropy, lm_loss
from repro.training.optimizer import AdamW
from repro.training.train_state import init_train_state, make_train_step
from tests.proptest import sweep


class TestAdamW:
    def test_quadratic_convergence(self):
        """AdamW minimizes a simple quadratic."""
        opt = AdamW(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                    schedule="constant", clip_norm=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        target = jnp.asarray([1.0, 2.0])
        for _ in range(300):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, _ = opt.update(grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_weight_decay_shrinks(self):
        opt = AdamW(learning_rate=0.1, weight_decay=1.0, warmup_steps=0,
                    schedule="constant", clip_norm=0.0)
        params = {"w": jnp.asarray([10.0])}
        state = opt.init(params)
        for _ in range(50):
            params, state, _ = opt.update({"w": jnp.zeros(1)}, state, params)
        assert abs(float(params["w"][0])) < 10.0 * 0.1

    def test_grad_clipping(self):
        opt = AdamW(learning_rate=1e-3, clip_norm=1.0, warmup_steps=0,
                    schedule="constant")
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        _, _, gnorm = opt.update({"w": jnp.asarray([1e4, 1e4, 1e4])},
                                 state, params)
        assert float(gnorm) > 1.0  # reported pre-clip norm

    def test_lr_schedule(self):
        opt = AdamW(learning_rate=1.0, warmup_steps=10, total_steps=100,
                    schedule="cosine")
        assert float(opt.lr_at(jnp.asarray(0))) == 0.0
        assert float(opt.lr_at(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(opt.lr_at(jnp.asarray(100))) == pytest.approx(0.0,
                                                                   abs=1e-6)


class TestLoss:
    @sweep(cases=15, seed=30)
    def test_cross_entropy_matches_naive(self, draw):
        b = draw.integers(1, 4)
        s = draw.integers(1, 8)
        v = draw.integers(4, 40)
        pad = draw.integers(0, 16)
        rng = np.random.default_rng(draw.integers(0, 999))
        logits = jnp.asarray(rng.normal(size=(b, s, v + pad)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
        got = float(cross_entropy(logits, labels, real_vocab=v))
        # naive reference on the unpadded slice
        lg = np.asarray(logits)[..., :v]
        lse = np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1)) \
            + lg.max(-1)
        gold = np.take_along_axis(lg, np.asarray(labels)[..., None],
                                  -1)[..., 0]
        np.testing.assert_allclose(got, float((lse - gold).mean()),
                                   rtol=1e-5)

    def test_mask(self):
        logits = jnp.zeros((1, 4, 8))
        labels = jnp.zeros((1, 4), jnp.int32)
        m = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        full = float(cross_entropy(logits, labels))
        masked = float(cross_entropy(logits, labels, mask=m))
        assert full == pytest.approx(masked)  # uniform logits: same nll


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
                "c": [jnp.ones(4), jnp.zeros((2, 2))]}
        path = str(tmp_path / "ckpt")
        checkpoint.save(path, tree, {"step": 7})
        restored = checkpoint.restore(path, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert checkpoint.load_metadata(path)["step"] == 7

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "ckpt")
        checkpoint.save(path, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            checkpoint.restore(path, {"w": jnp.zeros((3, 3))})

    def test_missing_leaf_raises(self, tmp_path):
        path = str(tmp_path / "ckpt")
        checkpoint.save(path, {"w": jnp.zeros(2)})
        with pytest.raises(KeyError):
            checkpoint.restore(path, {"w": jnp.zeros(2), "x": jnp.zeros(1)})


class TestEndToEnd:
    def test_memorize_batch(self):
        """A tiny model memorizes a repeated batch (loss falls >30%)."""
        cfg = get_reduced("qwen3-8b", vocab_size=64, d_model=64,
                          num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128)
        model = Model(cfg)
        opt = AdamW(learning_rate=3e-3, warmup_steps=0, schedule="constant",
                    total_steps=40)
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, opt, remat=False))
        batch = next(token_stream(64, 4, 32, 1, seed=1))
        losses = []
        for _ in range(30):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.7 * losses[0], losses[::6]

    def test_data_pipeline_deterministic(self):
        a = [np.asarray(b["tokens"]) for b in token_stream(128, 2, 16, 3,
                                                           seed=5)]
        b = [np.asarray(b["tokens"]) for b in token_stream(128, 2, 16, 3,
                                                           seed=5)]
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_workload_alpha_in_range(self):
        from repro.data.pipeline import make_workload
        domains, alphas = make_workload(8, 1000, 200)
        a = np.asarray(alphas)
        assert a.shape == (200, 8)
        assert np.all((a > 0.0) & (a < 1.0))
        # heterogeneity: distinct per-dataset means
        assert np.std(a.mean(axis=0)) > 0.05
