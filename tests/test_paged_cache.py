"""Paged (block-pool) KV cache: allocator edge cases and paged-vs-static
equivalence.  See docs/KV_CACHE.md for the invariants under test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.serving import kv_cache as kc
from repro.serving.engine import GoodSpeedEngine
from repro.serving.request import Request


def _assert_allocator_invariants(cache):
    """Refcount conservation: ``refcount[p]`` equals the number of table
    cells referencing block p — so free (refcount 0) blocks are never
    referenced, nothing leaks, and sharing is exactly what the tables
    declare.  For a share-free trace this reduces to the historical
    one-cell-per-block free-list invariant."""
    tbl = np.asarray(cache.table)
    ref = np.asarray(cache.refcount)
    counts = np.zeros_like(ref)
    np.add.at(counts, tbl[tbl >= 0], 1)
    np.testing.assert_array_equal(counts, ref,
                                  "refcount drifted from the block tables")
    free = np.asarray(cache.free)
    distinct = len(set(tbl[tbl >= 0].tolist()))
    assert free.sum() + distinct == free.shape[0], "leaked blocks"


def _views_match(paged, static):
    """Paged logical view == static cache on every valid slot."""
    views = kc.paged_view(paged)
    stat_vals = [static.ckv, static.kpe] if isinstance(static, kc.MLACache) \
        else [static.k, static.v]
    l = static.pos_arr.shape[1]
    valid = np.asarray(static.pos_arr >= 0)
    np.testing.assert_array_equal(np.asarray(paged.pos_arr)[:, :l],
                                  np.asarray(static.pos_arr))
    assert np.all(np.asarray(paged.pos_arr)[:, l:] == -1)
    for pv, sv in zip(views, stat_vals):
        pv, sv = np.asarray(pv), np.asarray(sv)
        mask = valid.reshape(valid.shape + (1,) * (sv.ndim - 2))
        np.testing.assert_array_equal(np.where(mask, pv[:, :l], 0),
                                      np.where(mask, sv, 0))


class TestPagedPrimitives:
    B, L, KV, HD, BS = 3, 32, 2, 4, 8

    def _pair(self):
        static = kc.init_attn_cache(self.B, self.L, self.KV, self.HD,
                                    jnp.float32)
        paged = kc.init_paged_attn_cache(self.B, self.L, self.KV, self.HD,
                                         jnp.float32, self.BS)
        return static, paged

    def _kv(self, rng, s):
        return (jnp.asarray(rng.normal(size=(self.B, s, self.KV, self.HD)),
                            jnp.float32),
                jnp.asarray(rng.normal(size=(self.B, s, self.KV, self.HD)),
                            jnp.float32))

    def test_prefill_chunk_rollback_match_static(self):
        """A full write/rollback trace keeps the paged view identical to
        the static cache and the allocator consistent."""
        rng = np.random.default_rng(0)
        static, paged = self._pair()
        lengths = jnp.asarray([5, 12, 1], jnp.int32)
        kv1 = self._kv(rng, 12)
        static = kc.write_prefill(static, kv1, lengths)
        paged = kc.write_prefill(paged, kv1, lengths)
        _views_match(paged, static)
        for step in range(3):
            kv2 = self._kv(rng, 4)
            valid = jnp.asarray(rng.random((self.B, 4)) < 0.8)
            static = kc.write_chunk(static, kv2, valid)
            paged = kc.write_chunk(paged, kv2, valid)
            _views_match(paged, static)
            _assert_allocator_invariants(paged)
            keep = jnp.maximum(static.next_pos - (step % 2), 0)
            static = kc.rollback(static, keep)
            paged = kc.rollback(paged, keep)
            _views_match(paged, static)
            _assert_allocator_invariants(paged)
        assert not bool(paged.alloc_failed)

    def test_rollback_frees_exactly_tail_blocks(self):
        """Rolling back m rejected tokens returns exactly the blocks that
        held ONLY speculative positions — no more, no fewer."""
        rng = np.random.default_rng(1)
        _, paged = self._pair()
        lengths = jnp.asarray([6, 6, 6], jnp.int32)   # 6 < BS=8: 1 block
        paged = kc.write_prefill(paged, self._kv(rng, 6), lengths)
        assert int(kc.paged_free_count(paged)) == paged.free.shape[0] - 3
        # a 5-token chunk crosses the block boundary at slot 8 -> 2 blocks
        paged = kc.write_chunk(paged, self._kv(rng, 5), None)
        used_before = paged.free.shape[0] - int(kc.paged_free_count(paged))
        assert used_before == 6
        # keep 7 tokens: slot 8..10 dropped -> the second block of every
        # row is exactly the speculative tail
        paged = kc.rollback(paged, jnp.asarray([7, 7, 7], jnp.int32))
        assert int(kc.paged_free_count(paged)) == paged.free.shape[0] - 3
        _assert_allocator_invariants(paged)
        # keep everything: rollback at next_pos frees nothing
        before = int(kc.paged_free_count(paged))
        paged = kc.rollback(paged, paged.next_pos)
        assert int(kc.paged_free_count(paged)) == before

    def test_reset_rows_frees_for_reuse(self):
        """Retiring a row returns all its blocks; a later prefill of a
        different row can claim them (admission reuse)."""
        rng = np.random.default_rng(2)
        paged = kc.init_paged_attn_cache(self.B, self.L, self.KV, self.HD,
                                         jnp.float32, self.BS,
                                         num_blocks=4)  # 4 blocks total
        lengths = jnp.asarray([16, 8, 0], jnp.int32)    # 2 + 1 + 0 blocks
        paged = kc.write_prefill(paged, self._kv(rng, 16), lengths)
        assert int(kc.paged_free_count(paged)) == 1
        paged = kc.reset_rows(paged, jnp.asarray([True, False, False]))
        assert int(kc.paged_free_count(paged)) == 3
        # row 2 now claims 3 blocks that mostly belonged to row 0
        k2, v2 = self._kv(rng, 20)
        sub = kc.paged_select_rows(paged, jnp.asarray([2]))
        sub = kc.write_prefill(sub, (k2[:1], v2[:1]),
                               jnp.asarray([20], jnp.int32))
        paged = kc.paged_merge_rows(paged, sub, jnp.asarray([2]))
        assert int(kc.paged_free_count(paged)) == 0
        assert not bool(paged.alloc_failed)
        _assert_allocator_invariants(paged)

    def test_pool_exhaustion_sets_sticky_flag(self):
        """Writes past the pool capacity are dropped and flagged, never
        silently corrupting other rows' blocks; slots whose block
        allocation failed stay invalid (pos_arr == -1), so attention can
        never gather another request's K/V through them."""
        rng = np.random.default_rng(3)
        tiny = kc.init_paged_attn_cache(self.B, self.L, self.KV, self.HD,
                                        jnp.float32, self.BS, num_blocks=2)
        tiny = kc.write_prefill(tiny, self._kv(rng, 12),
                                jnp.asarray([12, 12, 12], jnp.int32))
        assert bool(tiny.alloc_failed)
        _assert_allocator_invariants(tiny)
        tbl, pos = np.asarray(tiny.table), np.asarray(tiny.pos_arr)
        backed = np.take_along_axis(
            tbl, np.arange(pos.shape[1])[None, :] // self.BS, axis=1) >= 0
        assert not (pos[~backed] >= 0).any(), "valid slot without a block"

    def test_reprefill_does_not_leak_blocks(self):
        """write_prefill on rows that already hold blocks frees them first
        — repeated prefills never shrink the pool."""
        rng = np.random.default_rng(5)
        paged = kc.init_paged_attn_cache(self.B, self.L, self.KV, self.HD,
                                         jnp.float32, self.BS)
        for _ in range(3):
            paged = kc.write_prefill(paged, self._kv(rng, 12),
                                     jnp.asarray([12, 9, 5], jnp.int32))
            _assert_allocator_invariants(paged)
        # ceil(12/8) + ceil(9/8) + ceil(5/8) = 2 + 2 + 1 blocks held
        assert int(kc.paged_free_count(paged)) == paged.free.shape[0] - 5
        assert not bool(paged.alloc_failed)

    def test_paged_mla_cache_roundtrip(self):
        rng = np.random.default_rng(4)
        r, rope = 6, 4
        static = kc.init_mla_cache(self.B, self.L, r, rope, jnp.float32)
        paged = kc.init_paged_mla_cache(self.B, self.L, r, rope,
                                        jnp.float32, self.BS)
        vals = (jnp.asarray(rng.normal(size=(self.B, 10, r)), jnp.float32),
                jnp.asarray(rng.normal(size=(self.B, 10, rope)),
                            jnp.float32))
        lengths = jnp.asarray([10, 3, 7], jnp.int32)
        static = kc.write_prefill(static, vals, lengths)
        paged = kc.write_prefill(paged, vals, lengths)
        _views_match(paged, static)
        _assert_allocator_invariants(paged)


class TestPagedEngine:
    VOCAB = conftest.MIXED_TRACE_VOCAB

    @pytest.fixture(scope="class")
    def pair(self, serve_pair):
        return serve_pair

    def _requests(self, k, seed=11, max_new=5):
        return conftest.mixed_trace_requests(k, seed=seed, max_new=max_new,
                                             vocab=self.VOCAB)

    def _engine(self, dm, tm, paged, **kw):
        args = dict(draft_model=dm, target_model=tm, n_servers=2, C=8,
                    s_max=4, cache_len=128, paged_kv=paged,
                    kv_block_size=16)
        args.update(kw)
        return GoodSpeedEngine(**args)

    def test_paged_static_equivalence_mixed_trace(self, mixed_trace):
        """ACCEPTANCE: paged and static engines emit identical accepted-
        token sequences over a mixed admit/retire/EOS workload (same seed),
        and the paged run accounts per-request blocks."""
        reps = {p: mixed_trace(paged_kv=p) for p in (False, True)}
        seq = {p: conftest.generated_seqs(reps[p]) for p in reps}
        assert seq[True] == seq[False]
        assert all(r["kv_blocks"] == 1 for r in reps[True]["requests"])
        assert all(r["kv_blocks"] == 0 for r in reps[False]["requests"])

    def test_pool_exhaustion_clean_admission_error(self, pair):
        """An under-provisioned pool rejects admission with
        PoolExhaustedError instead of corrupting the cache."""
        dm, tm, dp, tp = pair
        # 2 blocks of 16 slots: a 40-token prompt needs 3 blocks
        eng = self._engine(dm, tm, True, kv_num_blocks=2, cache_len=64)
        long_prompt = np.arange(1, 41, dtype=np.int32) % self.VOCAB
        state = eng.cold_start(jax.random.PRNGKey(0))
        with pytest.raises(kc.PoolExhaustedError):
            eng._admit_rows(state, [0], {0: long_prompt}, dp, tp)

    def test_admission_reuses_freed_blocks(self, pair):
        """A pool too small for all requests at once still drains the
        workload because retirement frees blocks for the next admission."""
        dm, tm, dp, tp = pair
        # each request: 8-token prompt + 4 new + bonus -> 1 block of 16 is
        # plenty; 2 servers x 1 block live at a time, pool of 3
        eng = self._engine(dm, tm, True, kv_num_blocks=3, cache_len=16,
                           C=4, s_max=2)
        reqs = self._requests(5, max_new=4)
        for r in reqs:
            r.eos_token = -1
        rep = eng.serve_requests(jax.random.PRNGKey(2), reqs, dp, tp,
                                 rounds=80)
        assert rep["summary"]["completed"] == 5
        from repro.serving.engine import _first_paged_leaf
        _assert_allocator_invariants(_first_paged_leaf(
            rep["state"].target_cache))

    def test_idle_row_blocks_released_for_other_servers(self, pair):
        """A pool that only fits one live request at a time: once server
        0's request retires, its blocks must be releasable to a LATER
        admission on server 1 even though server 0 never re-admits."""
        dm, tm, dp, tp = pair
        eng = self._engine(dm, tm, True, n_servers=2, kv_block_size=8,
                           kv_num_blocks=3, cache_len=24, C=4, s_max=2)
        rng = np.random.default_rng(21)
        mk = lambda: Request(prompt=rng.integers(1, self.VOCAB, size=16)
                             .astype(np.int32), max_new_tokens=3)
        # 16-token prompt = 2 blocks at admission, 3 during decode; the
        # second request (server 1, round 10) only fits if server 0's
        # blocks were freed when its request finished
        rep = eng.serve_requests(jax.random.PRNGKey(5),
                                 [(0, 0, mk()), (10, 1, mk())], dp, tp,
                                 rounds=40)
        assert rep["summary"]["completed"] == 2

    def test_serve_matches_static_fixed_rounds(self, pair):
        """Fixed-round simulator path: same emitted tokens paged vs
        static (init-time prefill equivalence)."""
        dm, tm, dp, tp = pair
        rng = np.random.default_rng(7)
        prompts = [rng.integers(1, self.VOCAB, size=9).astype(np.int32)
                   for _ in range(2)]
        hists = {}
        for paged in (False, True):
            eng = self._engine(dm, tm, paged, C=6, s_max=3)
            hists[paged] = eng.serve(jax.random.PRNGKey(3), prompts, dp, tp,
                                     rounds=4)
        for h0, h1 in zip(hists[False], hists[True]):
            np.testing.assert_array_equal(h0.emitted, h1.emitted)
            np.testing.assert_array_equal(h0.S, h1.S)
