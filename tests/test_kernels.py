"""Pallas kernels vs pure-jnp oracles, swept over shapes and dtypes
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import flash_decode, flash_decode_ref
from repro.kernels.spec_verify import gather_logprobs, gather_logprobs_ref
from tests.proptest import sweep


class TestGatherLogprobs:
    @sweep(cases=25, seed=20)
    def test_matches_oracle(self, draw):
        r = draw.integers(1, 12)
        v = draw.choice([17, 128, 1000, 2048, 4096, 5001])
        tile = draw.choice([128, 512, 2048])
        dtype = draw.choice([jnp.float32, jnp.bfloat16])
        rng = np.random.default_rng(draw.integers(0, 9999))
        logits = jnp.asarray(rng.normal(size=(r, v)) * 4, dtype)
        toks = jnp.asarray(rng.integers(0, v, size=(r,)), jnp.int32)
        lp, lz = gather_logprobs(logits, toks, tile=tile)
        rlp, rlz = gather_logprobs_ref(logits, toks)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(lp), np.asarray(rlp),
                                   atol=tol, rtol=tol)
        np.testing.assert_allclose(np.asarray(lz), np.asarray(rlz),
                                   atol=tol, rtol=tol)

    def test_batched_leading_dims(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(3, 5, 300)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, 300, size=(3, 5)), jnp.int32)
        lp, lz = gather_logprobs(logits, toks)
        assert lp.shape == (3, 5) and lz.shape == (3, 5)
        rlp, _ = gather_logprobs_ref(logits.reshape(-1, 300),
                                     toks.reshape(-1))
        np.testing.assert_allclose(np.asarray(lp).reshape(-1),
                                   np.asarray(rlp), atol=1e-5)

    def test_extreme_logits_stable(self):
        """Online logsumexp stays finite with +/-1e4 logits."""
        logits = jnp.asarray([[1e4, -1e4, 0.0, 5.0] * 64], jnp.float32)
        toks = jnp.asarray([0], jnp.int32)
        lp, lz = gather_logprobs(logits, toks, tile=128)
        assert np.isfinite(float(lp[0])) and np.isfinite(float(lz[0]))
        rlp, _ = gather_logprobs_ref(logits, toks)
        np.testing.assert_allclose(float(lp[0]), float(rlp[0]), atol=1e-4)


class TestFlashDecode:
    @sweep(cases=25, seed=21)
    def test_matches_oracle(self, draw):
        b = draw.integers(1, 3)
        kv = draw.choice([1, 2, 4])
        g = draw.choice([1, 2, 4])
        h = kv * g
        hd = draw.choice([32, 64, 128])
        l = draw.choice([32, 64, 96, 160])
        tile = draw.choice([16, 32, 64])
        window = draw.choice([0, 0, 24])
        dtype = draw.choice([jnp.float32, jnp.bfloat16])
        rng = np.random.default_rng(draw.integers(0, 9999))
        q = jnp.asarray(rng.normal(size=(b, h, hd)), dtype)
        k = jnp.asarray(rng.normal(size=(b, l, kv, hd)), dtype)
        v = jnp.asarray(rng.normal(size=(b, l, kv, hd)), dtype)
        # realistic cache: some slots filled (ascending pos), some empty
        fill = rng.integers(l // 2, l + 1, size=(b,))
        kv_pos = np.full((b, l), -1, np.int32)
        for i in range(b):
            kv_pos[i, :fill[i]] = np.arange(fill[i])
        kv_pos = jnp.asarray(kv_pos)
        q_pos = jnp.asarray(fill - 1, jnp.int32)
        out = flash_decode(q, k, v, kv_pos, q_pos, window=window, tile=tile)
        ref = flash_decode_ref(q, k, v, kv_pos, kv_pos >= 0, q_pos,
                               window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=tol, rtol=tol)

    def test_ring_buffer_positions(self):
        """Wrapped (non-monotonic) pos_arr from a sliding ring buffer."""
        rng = np.random.default_rng(3)
        b, h, kv, hd, l = 1, 4, 2, 32, 8
        q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        # positions 8..15 written into slots (8..15) % 8 -> slot i has pos 8+i
        kv_pos = jnp.asarray([[8, 9, 10, 11, 12, 13, 14, 15]], jnp.int32)
        kv_pos = jnp.roll(kv_pos, 3, axis=1)  # arbitrary rotation
        q_pos = jnp.asarray([15], jnp.int32)
        out = flash_decode(q, k, v, kv_pos, q_pos, window=6, tile=4)
        ref = flash_decode_ref(q, k, v, kv_pos, kv_pos >= 0, q_pos, window=6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_single_valid_slot(self):
        b, h, kv, hd, l = 1, 2, 1, 16, 16
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, l, kv, hd)), jnp.float32)
        kv_pos = jnp.full((b, l), -1, jnp.int32).at[0, 0].set(0)
        q_pos = jnp.asarray([0], jnp.int32)
        out = flash_decode(q, k, v, kv_pos, q_pos, tile=8)
        # attention over one slot = that slot's value
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(v[0, 0, 0])[None, None, :]
                                   .repeat(h, 1), atol=1e-5)


class TestFlashPrefill:
    @sweep(cases=20, seed=22)
    def test_matches_oracle(self, draw):
        from repro.kernels.flash_prefill import (flash_prefill,
                                                 flash_prefill_ref)
        b = draw.integers(1, 3)
        kv = draw.choice([1, 2, 4])
        g = draw.choice([1, 2, 4])
        h = kv * g
        hd = draw.choice([16, 32, 64])
        tile = draw.choice([8, 16, 32])
        s = tile * draw.integers(1, 4)
        window = draw.choice([0, 0, 10])
        dtype = draw.choice([jnp.float32, jnp.bfloat16])
        rng = np.random.default_rng(draw.integers(0, 9999))
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
        k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
        v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
        out = flash_prefill(q, k, v, window=window, q_tile=tile,
                            kv_tile=tile)
        ref = flash_prefill_ref(q, k, v, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=tol, rtol=tol)

    def test_first_position_attends_self_only(self):
        from repro.kernels.flash_prefill import flash_prefill
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
        out = flash_prefill(q, k, v, q_tile=8, kv_tile=8)
        np.testing.assert_allclose(np.asarray(out[0, 0]),
                                   np.asarray(v[0, 0]), atol=1e-5)
