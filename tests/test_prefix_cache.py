"""Refcounted prefix caching with copy-on-write sharing in the paged KV
pool — plus the paged/static chunk-write overflow regression.

Covers (see docs/KV_CACHE.md for the invariants):
  * ``serving.prefix.PrefixIndex`` — chain keying, first-writer-wins
    registration, eviction semantics.
  * kv_cache primitives — prefix attach (refcount bump, no copy),
    attach-before-allocate re-pinning, copy-on-write when a chunk write
    lands in a block with refcount > 1, and the sticky ``overflowed``
    flag replacing the old silent clamp-onto-the-last-slot bug.
  * engine admission — pre-check == actual allocation (property sweep
    over 1-token prompts, block boundaries and mixed batches), refcount
    conservation, exhaustion raised BEFORE any mutation, 1/refcount
    block attribution summing to exactly P - free_count.
  * shared-prefix serving equivalence — ``prefix_cache=True`` emits the
    IDENTICAL accepted-token sequences as the baseline engine across
    jnp x kernel backends, sync x overlap rounds, and lanes {1, 2}.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conftest
from repro.serving import kv_cache as kc
from repro.serving.engine import GoodSpeedEngine, _paged_alloc_state
from repro.serving.prefix import PrefixIndex
from repro.serving.request import Request, RequestManager
from tests.proptest import sweep


def _paged_leaves(cache):
    """All paged leaves of a stack cache, scan-group stacks unstacked."""
    leaf = lambda c: isinstance(c, kc.PAGED_TYPES)
    out = []
    for c in jax.tree.leaves(cache, is_leaf=leaf):
        if not isinstance(c, kc.PAGED_TYPES):
            continue
        if c.table.ndim == 3:                        # [G, B, M] scan stack
            out.extend(jax.tree.map(lambda x, i=i: x[i], c)
                       for i in range(c.table.shape[0]))
        else:
            out.append(c)
    return out


def _assert_conserved(leaf):
    """refcount[p] == number of table cells referencing block p, so free
    (refcount 0) blocks are never referenced and nothing leaks."""
    tbl = np.asarray(leaf.table)
    ref = np.asarray(leaf.refcount)
    counts = np.zeros_like(ref)
    np.add.at(counts, tbl[tbl >= 0], 1)
    np.testing.assert_array_equal(counts, ref,
                                  "refcount drifted from the block tables")


def _assert_state_conserved(state):
    for cache in (state.target_cache, state.draft_cache):
        for leaf in _paged_leaves(cache):
            _assert_conserved(leaf)


def _free_count(cache) -> int:
    return int(np.asarray(_paged_alloc_state(cache)[1]).sum())


# ---------------------------------------------------------------------------
# PrefixIndex: the host-side content map
# ---------------------------------------------------------------------------

class TestPrefixIndex:
    def test_match_longest_chain_and_counters(self):
        ix = PrefixIndex()
        toks = np.arange(24, dtype=np.int32)      # np ints must normalize
        ix.register(toks, [5, 9, 11], 8)
        assert ix.match(list(range(24)), 8) == [5, 9, 11]
        assert ix.match(toks[:17], 8) == [5, 9]   # 2 full blocks only
        assert ix.match(list(toks[:8]) + [99] * 8, 8) == [5]
        assert ix.match([99] * 8, 8) == []
        assert ix.match([1, 2, 3], 8) == []       # no full block: no miss
        assert ix.hits == 3 and ix.misses == 1

    def test_chain_key_is_full_prefix_not_block_content(self):
        """Block 1's K/V depends on block 0's tokens through attention:
        identical block-1 CONTENT under a different prefix must miss."""
        ix = PrefixIndex()
        ix.register([1] * 8 + [2] * 8, [3, 4], 8)
        assert ix.match([9] * 8 + [2] * 8, 8) == []

    def test_first_writer_wins_and_eviction(self):
        ix = PrefixIndex()
        toks = list(range(16))
        ix.register(toks, [0, 1], 8)
        ix.register(toks, [7, 8], 8)              # later writer ignored
        assert ix.match(toks, 8) == [0, 1]
        ix.evict_blocks([1])
        assert ix.match(toks, 8) == [0]
        ix.evict_free(np.asarray([0, 3]))         # refcount[block 0] == 0
        assert ix.match(toks, 8) == []
        assert not ix.by_key and not ix.by_block


# ---------------------------------------------------------------------------
# kv_cache primitives: attach / re-pin / COW / overflow
# ---------------------------------------------------------------------------

class TestSharedPagedPrimitives:
    B, L, KV, HD, BS = 3, 32, 2, 4, 8

    def _cache(self, num_blocks=0):
        return kc.init_paged_attn_cache(self.B, self.L, self.KV, self.HD,
                                        jnp.float32, self.BS,
                                        num_blocks=num_blocks)

    def _kv(self, rng, s):
        return (jnp.asarray(rng.normal(size=(self.B, s, self.KV, self.HD)),
                            jnp.float32),
                jnp.asarray(rng.normal(size=(self.B, s, self.KV, self.HD)),
                            jnp.float32))

    def test_attach_shares_donor_blocks_without_copy(self):
        """Attaching a 2-block prefix bumps refcounts and reuses the
        donor's physical blocks; only the suffix allocates new ones, and
        the shared region reads back the DONOR's K/V."""
        rng = np.random.default_rng(0)
        cache = self._cache()
        kv_a = self._kv(rng, 16)
        cache = kc.write_prefill(cache, kv_a, jnp.asarray([16, 0, 0]))
        blocks = np.asarray(cache.table)[0, :2]
        assert np.all(blocks >= 0)

        idx = jnp.asarray([1, 2])
        sub = kc.paged_select_rows(cache, idx)
        kv_s = tuple(v[idx] for v in self._kv(rng, 4))
        sub = kc.paged_write_prefill(
            sub, kv_s, jnp.asarray([4, 0]),
            shared_blocks=jnp.asarray([blocks, blocks]),
            shared_lens=jnp.asarray([16, 16]))
        cache = kc.paged_merge_rows(cache, sub, idx)

        ref = np.asarray(cache.refcount)
        tbl = np.asarray(cache.table)
        assert ref[blocks[0]] == 3 and ref[blocks[1]] == 3
        np.testing.assert_array_equal(tbl[1, :2], blocks)
        np.testing.assert_array_equal(tbl[2, :2], blocks)
        _assert_conserved(cache)
        # exactly ONE new block (row 1's 4-token suffix); row 2 has none
        assert int(np.asarray(cache.free).sum()) == ref.shape[0] - 3
        k_view, v_view = [np.asarray(v) for v in kc.paged_view(cache)]
        for row in (1, 2):
            np.testing.assert_array_equal(k_view[row, :16],
                                          np.asarray(kv_a[0])[0])
        np.testing.assert_array_equal(k_view[1, 16:20],
                                      np.asarray(kv_s[0])[0])
        np.testing.assert_array_equal(np.asarray(cache.next_pos),
                                      [16, 20, 16])
        assert not bool(cache.alloc_failed)

    def test_attach_repins_blocks_freed_by_own_reset(self):
        """Re-admitting the donor row in the SAME prefill that attaches
        its old blocks: attachment happens before suffix allocation, so
        the dying blocks are re-pinned (content intact) and the donor's
        new prompt lands in OTHER blocks."""
        rng = np.random.default_rng(1)
        cache = self._cache()
        kv_a = self._kv(rng, 16)
        cache = kc.write_prefill(cache, kv_a, jnp.asarray([16, 0, 0]))
        blocks = np.asarray(cache.table)[0, :2]

        kv_b = self._kv(rng, 16)
        shared = jnp.asarray([[-1, -1], blocks, blocks])
        cache = kc.write_prefill(cache, kv_b, jnp.asarray([16, 4, 4]),
                                 shared_blocks=shared,
                                 shared_lens=jnp.asarray([0, 16, 16]))
        tbl = np.asarray(cache.table)
        ref = np.asarray(cache.refcount)
        assert ref[blocks[0]] == 2 and ref[blocks[1]] == 2
        assert not set(tbl[0, :2].tolist()) & set(blocks.tolist())
        _assert_conserved(cache)
        k_view, _ = [np.asarray(v) for v in kc.paged_view(cache)]
        # rows 1, 2 read the ORIGINAL donor K/V, not row 0's new prefill
        np.testing.assert_array_equal(k_view[1, :16], np.asarray(kv_a[0])[0])
        np.testing.assert_array_equal(k_view[0, :16], np.asarray(kv_b[0])[0])
        assert not bool(cache.alloc_failed)

    def test_cow_chunk_write_preserves_the_other_sharer(self):
        """A chunk write landing inside a block with refcount > 1 copies
        it first: the writer gets a private block, the other holder's
        view is untouched, and the refcount splits."""
        rng = np.random.default_rng(2)
        cache = self._cache()
        kv_a = self._kv(rng, 8)
        cache = kc.write_prefill(cache, kv_a, jnp.asarray([8, 0, 0]))
        b0 = int(np.asarray(cache.table)[0, 0])

        idx = jnp.asarray([1])
        sub = kc.paged_select_rows(cache, idx)
        z = jnp.zeros((1, 1, self.KV, self.HD), jnp.float32)
        sub = kc.paged_write_prefill(sub, (z, z), jnp.asarray([0]),
                                     shared_blocks=jnp.asarray([[b0]]),
                                     shared_lens=jnp.asarray([8]))
        cache = kc.paged_merge_rows(cache, sub, idx)
        assert int(np.asarray(cache.refcount)[b0]) == 2

        # roll row 1 back INTO the shared block, then write over it
        cache = kc.paged_rollback(cache, jnp.asarray([8, 6, 0]))
        kv_c = self._kv(rng, 3)
        valid = jnp.asarray([[False] * 3, [True] * 3, [False] * 3])
        cache = kc.paged_write_chunk(cache, kv_c, valid)

        tbl = np.asarray(cache.table)
        ref = np.asarray(cache.refcount)
        assert tbl[0, 0] == b0 and ref[b0] == 1   # donor keeps the block
        assert tbl[1, 0] != b0                    # writer got a COW copy
        _assert_conserved(cache)
        k_view, _ = [np.asarray(v) for v in kc.paged_view(cache)]
        np.testing.assert_array_equal(k_view[0, :8], np.asarray(kv_a[0])[0])
        np.testing.assert_array_equal(k_view[1, :6],
                                      np.asarray(kv_a[0])[0, :6])
        np.testing.assert_array_equal(k_view[1, 6:9], np.asarray(kv_c[0])[1])
        assert int(np.asarray(cache.next_pos)[1]) == 9
        assert not bool(cache.alloc_failed)

    @pytest.mark.parametrize("paged", [False, True])
    def test_chunk_overflow_drops_write_and_sets_sticky_flag(self, paged):
        """REGRESSION: a chunk write past cache_len used to clamp onto
        slot L-1, silently destroying the last committed token's K/V.
        It must now DROP the write, freeze the counter, and set the
        sticky per-row ``overflowed`` flag."""
        rng = np.random.default_rng(3)
        cache = self._cache() if paged else kc.init_attn_cache(
            self.B, self.L, self.KV, self.HD, jnp.float32)
        kv_p = self._kv(rng, 30)
        cache = kc.write_prefill(cache, kv_p, jnp.asarray([30, 5, 0]))
        kv_c = self._kv(rng, 4)
        cache = kc.write_chunk(cache, kv_c, None)

        np.testing.assert_array_equal(np.asarray(cache.overflowed),
                                      [True, False, False])
        np.testing.assert_array_equal(np.asarray(cache.next_pos),
                                      [32, 9, 4])
        k_view = np.asarray(kc.paged_view(cache)[0] if paged else cache.k)
        # slot 31 holds the token that BELONGS there (chunk token 1),
        # not the clamped 4th token of the old bug
        np.testing.assert_array_equal(k_view[0, 31], np.asarray(kv_c[0])[0, 1])
        assert int(np.asarray(cache.pos_arr)[0, 31]) == 31
        # the flag is sticky across rollback, cleared by row reset
        cache = kc.rollback(cache, jnp.minimum(cache.next_pos, 20))
        assert bool(np.asarray(cache.overflowed)[0])
        cache = kc.reset_rows(cache, jnp.asarray([True, False, False]))
        assert not np.asarray(cache.overflowed).any()

    def test_discard_tail_restores_overflow_snapshot(self):
        """Overlap reconciliation: discarding the speculative tail must
        also restore the pre-ahead sticky flags (an ahead-write overflow
        that got discarded never happened)."""
        rng = np.random.default_rng(4)
        cache = self._cache()
        cache = kc.write_prefill(cache, self._kv(rng, 30),
                                 jnp.asarray([30, 30, 30]))
        flags = kc.snapshot_sticky_flags(cache)
        keep = cache.next_pos
        cache = kc.write_chunk(cache, self._kv(rng, 4), None)
        assert np.asarray(cache.overflowed).all()
        cache = kc.discard_tail(cache, keep, flags.alloc_failed,
                                flags.overflowed)
        assert not np.asarray(cache.overflowed).any()
        _assert_conserved(cache)


# ---------------------------------------------------------------------------
# Engine admission: pre-check accuracy, conservation, accounting
# ---------------------------------------------------------------------------

BS = 8


@pytest.fixture(scope="module")
def prefix_eng(serve_pair):
    """One shared prefix-caching engine (4 rows, block size 8) so the
    admission-shape jit cache is reused across the tests below."""
    dm, tm, dp, tp = serve_pair
    eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=4,
                          C=8, s_max=4, cache_len=64, paged_kv=True,
                          kv_block_size=BS, prefix_cache=True)
    return eng, dp, tp


def _prompt(rng, n):
    return rng.integers(1, conftest.MIXED_TRACE_VOCAB,
                        size=n).astype(np.int32)


class TestPrefixAdmission:
    def test_validation_requires_paged_pure_attention(self, serve_pair):
        from repro.configs import get_reduced
        from repro.models import Model
        dm, tm, _, _ = serve_pair
        with pytest.raises(ValueError, match="paged_kv"):
            GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=2,
                            C=8, s_max=4, cache_len=64, prefix_cache=True)
        ring = Model(get_reduced("h2o-danube-3-4b", num_layers=2,
                                 d_model=64, num_heads=2, num_kv_heads=2,
                                 head_dim=32, d_ff=128,
                                 vocab_size=conftest.MIXED_TRACE_VOCAB))
        with pytest.raises(ValueError, match="pure-attention"):
            GoodSpeedEngine(draft_model=ring, target_model=tm, n_servers=2,
                            C=8, s_max=4, cache_len=64, paged_kv=True,
                            prefix_cache=True)

    def test_second_admission_attaches_donor_blocks(self, prefix_eng):
        """A later arrival sharing the donor's 2-block prompt prefix
        attaches those physical blocks (refcount 2) and allocates only
        its 1-block unique suffix."""
        eng, dp, tp = prefix_eng
        rng = np.random.default_rng(10)
        state = eng.cold_start(jax.random.PRNGKey(0))
        p0 = _prompt(rng, 17)                              # feed 16: 2 blocks
        state = eng._admit_rows(state, [0], {0: p0}, dp, tp)
        free0 = _free_count(state.target_cache)
        p1 = np.concatenate([p0[:16], _prompt(rng, 4)])    # feed 19
        state = eng._admit_rows(state, [1], {1: p1}, dp, tp)

        assert _free_count(state.target_cache) == free0 - 1
        for cache in (state.target_cache, state.draft_cache):
            for leaf in _paged_leaves(cache):
                tbl = np.asarray(leaf.table)
                ref = np.asarray(leaf.refcount)
                np.testing.assert_array_equal(tbl[1, :2], tbl[0, :2])
                assert np.all(ref[tbl[0, :2]] == 2)
                assert tbl[1, 2] >= 0 and tbl[1, 2] not in tbl[0, :2]
                _assert_conserved(leaf)
        for name in ("target", "draft"):
            assert eng._prefix_index[name].hits == 1

    def test_precheck_matches_actual_allocation(self, prefix_eng):
        """Property: the admission pre-check's block count is EXACT —
        the free-list delta of every admission equals the predicted
        suffix blocks, over 1-token prompts, block-boundary lengths and
        mixed shared/unshared batches; refcounts stay conserved."""
        eng, dp, tp = prefix_eng

        @sweep(cases=5, seed=7)
        def prop(draw):
            self._precheck_case(eng, dp, tp, draw)
        prop()

    def _precheck_case(self, eng, dp, tp, draw):
        rng = np.random.default_rng(draw.integers(0, 10_000))
        state = eng.cold_start(jax.random.PRNGKey(1))

        len0 = draw.choice([2, 9, 17, 24])                 # feed 1|8|16|23
        feed0 = len0 - 1
        p0 = _prompt(rng, len0)
        free_before = _free_count(state.target_cache)
        state = eng._admit_rows(state, [0], {0: p0}, dp, tp)
        delta = free_before - _free_count(state.target_cache)
        assert delta == kc.blocks_for(feed0, BS)
        _assert_state_conserved(state)

        # mixed batch of sharers: common prefix 0 | 1 | 2 full blocks
        rows = list(range(1, 1 + draw.integers(1, 3)))
        chain = (feed0 // BS) * BS
        prompts, expect, commons = {}, 0, []
        for i in rows:
            common = min(draw.choice([0, BS, 2 * BS]), chain)
            suffix = draw.choice([1, 2, BS, BS + 1])
            prompts[i] = np.concatenate([p0[:common], _prompt(rng, suffix)])
            expect += kc.blocks_for(len(prompts[i]) - 1 - common, BS)
            commons.append(common)
        free_before = _free_count(state.target_cache)
        state = eng._admit_rows(state, rows, prompts, dp, tp)
        assert free_before - _free_count(state.target_cache) == expect
        _assert_state_conserved(state)

        # re-admit the donor: its shared blocks survive via the sharers'
        # refcounts, its private blocks free, the new prompt allocates
        maxcommon = max(commons)
        new_len = draw.choice([2, 9, 17])
        pn = _prompt(rng, new_len)
        free_before = _free_count(state.target_cache)
        state = eng._admit_rows(state, [0], {0: pn}, dp, tp)
        freed = kc.blocks_for(feed0, BS) - maxcommon // BS
        assert _free_count(state.target_cache) \
            == free_before + freed - kc.blocks_for(new_len - 1, BS)
        _assert_state_conserved(state)

    def test_exhaustion_raised_before_any_mutation(self, serve_pair):
        """Sharing makes an admission fit that would exhaust the pool
        unshared; a genuinely over-budget admission still raises
        PoolExhaustedError with the pool state untouched."""
        dm, tm, dp, tp = serve_pair
        kw = dict(draft_model=dm, target_model=tm, n_servers=3, C=8,
                  s_max=4, cache_len=32, paged_kv=True, kv_block_size=BS,
                  kv_num_blocks=3)
        rng = np.random.default_rng(11)
        p0 = _prompt(rng, 17)                      # feed 16: 2 of 3 blocks
        p1 = np.concatenate([p0[:16], _prompt(rng, 2)])    # feed 17

        plain = GoodSpeedEngine(**kw)
        state = plain.cold_start(jax.random.PRNGKey(2))
        state = plain._admit_rows(state, [0], {0: p0}, dp, tp)
        with pytest.raises(kc.PoolExhaustedError, match="exhausted"):
            plain._admit_rows(state, [1], {1: p1}, dp, tp)

        eng = GoodSpeedEngine(**kw, prefix_cache=True)
        state = eng.cold_start(jax.random.PRNGKey(2))
        state = eng._admit_rows(state, [0], {0: p0}, dp, tp)
        state = eng._admit_rows(state, [1], {1: p1}, dp, tp)   # 1 block
        assert _free_count(state.target_cache) == 0
        free = _free_count(state.target_cache)
        ref_before = np.asarray(
            _paged_leaves(state.target_cache)[0].refcount).copy()
        p2 = np.concatenate([p0[:16], _prompt(rng, 10)])   # needs 2 more
        with pytest.raises(kc.PoolExhaustedError, match="exhausted"):
            eng._admit_rows(state, [2], {2: p2}, dp, tp)
        assert _free_count(state.target_cache) == free
        np.testing.assert_array_equal(
            np.asarray(_paged_leaves(state.target_cache)[0].refcount),
            ref_before)

    def test_kv_blocks_are_refcount_attributed_shares(self, prefix_eng):
        """REGRESSION (stale accounting): ``kv_blocks`` is recomputed
        from the live table with 1/refcount shares, so the per-request
        attributions sum to EXACTLY the allocated block count and
        ``kv_blocks_active == P - free_count``."""
        eng, dp, tp = prefix_eng
        rng = np.random.default_rng(12)
        state = eng.cold_start(jax.random.PRNGKey(3))
        mgr = RequestManager(4)
        p0 = _prompt(rng, 17)                              # 2 blocks
        p1 = np.concatenate([p0[:16], _prompt(rng, 4)])    # 2 shared + 1
        mgr.submit(0, Request(prompt=p0, max_new_tokens=4))
        fresh = mgr.admit()
        state = eng._admit_rows(state, fresh,
                                {i: mgr.active[i].prompt for i in fresh},
                                dp, tp)
        mgr.submit(1, Request(prompt=p1, max_new_tokens=4))
        fresh = mgr.admit()
        state = eng._admit_rows(state, fresh,
                                {i: mgr.active[i].prompt for i in fresh},
                                dp, tp)
        eng._refresh_kv_blocks(state, mgr)

        rows = [mgr.active[i] for i in range(4) if mgr.active[i] is not None]
        assert len(rows) == 2
        assert rows[0].kv_blocks == pytest.approx(1.0)     # 2 * 1/2
        assert rows[1].kv_blocks == pytest.approx(2.0)     # 2 * 1/2 + 1
        leaf = _paged_leaves(state.target_cache)[0]
        allocated = leaf.refcount.shape[0] - _free_count(state.target_cache)
        assert mgr.stats()["kv_blocks_active"] == pytest.approx(allocated)
        assert allocated == 3

    def test_release_evicts_only_last_holder_blocks(self, prefix_eng):
        """Releasing one sharer keeps the index entries alive (the other
        holder still pins the blocks); releasing the last holder evicts
        them, and a fresh admission gets NO stale match."""
        eng, dp, tp = prefix_eng
        rng = np.random.default_rng(13)
        state = eng.cold_start(jax.random.PRNGKey(4))
        p0 = _prompt(rng, 17)
        p1 = np.concatenate([p0[:16], _prompt(rng, 4)])
        state = eng._admit_rows(state, [0], {0: p0}, dp, tp)
        state = eng._admit_rows(state, [1], {1: p1}, dp, tp)
        assert len(eng._prefix_index["target"].by_block) >= 2
        state = eng._release_rows(state, [0])
        # row 1 still holds the shared chain: entries survive
        assert len(eng._prefix_index["target"].by_block) >= 2
        state = eng._release_rows(state, [1])
        assert not eng._prefix_index["target"].by_block
        assert not eng._prefix_index["draft"].by_block
        _assert_state_conserved(state)
        p2 = np.concatenate([p0[:16], _prompt(rng, 2)])
        free_before = _free_count(state.target_cache)
        state = eng._admit_rows(state, [2], {2: p2}, dp, tp)
        # full re-prefill: nothing stale to attach
        assert free_before - _free_count(state.target_cache) \
            == kc.blocks_for(len(p2) - 1, BS)


# ---------------------------------------------------------------------------
# serve(): the overflow health check (fixed-round path has no budget bound)
# ---------------------------------------------------------------------------

class TestServeOverflowCheck:
    @pytest.mark.parametrize("paged", [False, True])
    def test_serve_raises_on_capacity_overrun(self, serve_pair, paged):
        """REGRESSION: a fixed-round serve whose rows outrun cache_len
        used to decode on against silently truncated K/V; it must now
        fail loudly, naming the overrun rows."""
        dm, tm, dp, tp = serve_pair
        eng = GoodSpeedEngine(draft_model=dm, target_model=tm, n_servers=1,
                              C=4, s_max=4, cache_len=24, paged_kv=paged,
                              kv_block_size=BS)
        rng = np.random.default_rng(14)
        with pytest.raises(kc.CacheOverflowError, match=r"row\(s\) \[0\]"):
            eng.serve(jax.random.PRNGKey(5), [_prompt(rng, 8)], dp, tp,
                      rounds=30)


# ---------------------------------------------------------------------------
# Serving equivalence: prefix_cache=True emits IDENTICAL accepted tokens
# ---------------------------------------------------------------------------

def _shared_prefix_requests(k=6, prefix_len=33, max_new=5, seed=21):
    """Arrival workload with a long common system-prompt prefix (2 full
    16-token blocks) and short unique suffixes — EOS on odd indices like
    the acceptance mixed trace."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, conftest.MIXED_TRACE_VOCAB, size=prefix_len)
    reqs = []
    for i in range(k):
        suffix = rng.integers(1, conftest.MIXED_TRACE_VOCAB, size=1 + i % 4)
        reqs.append(Request(
            prompt=np.concatenate([prefix, suffix]).astype(np.int32),
            max_new_tokens=max_new, eos_token=(4 if i % 2 else -1)))
    return reqs


def _run_shared(serve_pair, **engine_kw):
    dm, tm, dp, tp = serve_pair
    kw = dict(draft_model=dm, target_model=tm, n_servers=2, C=8, s_max=4,
              cache_len=128, paged_kv=True, kv_block_size=16)
    kw.update(engine_kw)
    eng = GoodSpeedEngine(**kw)
    rep = eng.serve_requests(jax.random.PRNGKey(0),
                             _shared_prefix_requests(), dp, tp, rounds=60)
    assert rep["summary"]["completed"] == 6
    return eng, rep


@pytest.mark.slow
class TestPrefixEquivalenceTrace:
    """``prefix_cache=True`` must emit the IDENTICAL accepted-token
    sequences as the baseline paged engine on a shared-prefix workload:
    the attached blocks hold bitwise the same K/V the row's own prefill
    would have written."""

    @pytest.fixture(scope="class")
    def baseline(self, serve_pair):
        cache = {}

        def get(lanes):
            if lanes not in cache:
                _, rep = _run_shared(serve_pair, lanes=lanes)
                cache[lanes] = conftest.generated_seqs(rep)
            return cache[lanes]
        return get

    @pytest.mark.parametrize("backend,overlap", [
        ("jnp", False), ("kernel", False), ("jnp", True), ("kernel", True)])
    def test_sharing_matches_baseline(self, serve_pair, baseline, backend,
                                      overlap):
        eng, rep = _run_shared(serve_pair, prefix_cache=True,
                               attn_backend=backend, overlap=overlap)
        assert conftest.generated_seqs(rep) == baseline(1)
        # sharing actually happened: later arrivals hit the index
        assert eng._prefix_index["target"].hits > 0
        assert eng._prefix_index["draft"].hits > 0

    @pytest.mark.parametrize("overlap", [False, True])
    def test_sharing_matches_baseline_lanes2(self, serve_pair, baseline,
                                             overlap):
        eng, rep = _run_shared(serve_pair, lanes=2, prefix_cache=True,
                               overlap=overlap)
        assert conftest.generated_seqs(rep) == baseline(2)
        assert eng._prefix_index["target"].hits > 0
