"""Property-based placement tests (ISSUE 4): random arrival traces x
policies, driven through the REAL ``RequestManager`` host loop (no
models), plus the engine-level equivalence and fairness pins.

Invariants:
  * conservation — no request lost or duplicated, under every policy;
  * ``static`` reproduces the pre-PR per-server FIFO admission order
    exactly (diffed against an independent reference simulation, and at
    the engine level against a legacy direct-submit manager on the
    ACCEPTANCE mixed trace — byte-identical accepted tokens);
  * ``jsq`` never places on a strictly-worse queue (the chosen server's
    backlog at decision time is minimal);
  * ``goodput`` falls back to jsq decisions while every ``alpha_hat``
    still sits at ``alpha_init`` (cold estimates);
  * the paged-KV pool pre-check DEFERS admissions instead of raising
    ``PoolExhaustedError``, for every policy;
  * queue-wait aging is honest: a still-queued request's ``queue_wait``
    equals the rounds elapsed since its arrival.

The long random-trace sweeps carry the ``slow`` marker so they can be
deselected (`-m "not slow"`); a small sweep stays unmarked for quick
iteration.  ``make placement-check`` runs this module standalone.
"""
from collections import deque

import jax
import numpy as np
import pytest

import conftest
from benchmarks.common import jain
from repro.serving.engine import GoodSpeedEngine
from repro.serving.placement import (GoodputPlacement, JSQPlacement,
                                     PlacementPolicy, PlacementView,
                                     make_placement)
from repro.serving.request import Request, RequestManager
from tests.proptest import sweep

EMIT_W = 4      # emitted-row width of the model-free driver


class _Spy(PlacementPolicy):
    """Wraps a policy; records (request idx, backlog-at-decision, choice)
    without changing behaviour."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"spy:{inner.name}"
        self.log: list = []

    def place(self, request, view):
        srv = self.inner.place(request, view)
        self.log.append((request.request_id, view.backlog().copy(), srv))
        return srv


# -- model-free driver ------------------------------------------------------

def _trace(draw, n, k, horizon, min_prompt=1):
    """[(arrival_round, server_hint, prompt_len, max_new, eos_token)],
    sorted by arrival round (stable, like the engine's workload sort)."""
    items = [(draw.integers(0, horizon), draw.integers(0, n - 1),
              draw.integers(min_prompt, 8), draw.integers(1, 6),
              3 if j % 3 == 0 else -1) for j in range(k)]
    items.sort(key=lambda t: t[0])
    return items


def _emitted_row(r, i):
    """Deterministic emission per (round, server): 1-3 tokens in 1..5, so
    EOS (3) shows up and exercises mid-stream retirement."""
    cnt = (r * 31 + i * 7) % 3 + 1
    toks = [((r + i + j) % 5 + 1) for j in range(cnt)]
    return toks + [-1] * (EMIT_W - cnt)


def _drive(mgr, trace, rounds, view_fn=None):
    """serve_requests' host loop without models: submit arrivals, admit
    against a (possibly synthetic) view, feed deterministic emissions.
    Returns (requests, admission events as (round, server, trace_idx))."""
    n = mgr.n
    reqs = [Request(prompt=np.zeros(pl, np.int32), max_new_tokens=mn,
                    eos_token=eos) for (_, _, pl, mn, eos) in trace]
    idx_of = {r.request_id: j for j, r in enumerate(reqs)}
    events, idx = [], 0
    for r in range(rounds):
        while idx < len(trace) and trace[idx][0] <= r:
            mgr.submit(trace[idx][1], reqs[idx])
            idx += 1
        fresh = mgr.admit(view_fn(mgr) if view_fn else None)
        for i in fresh:
            events.append((r, i, idx_of[mgr.active[i].request_id]))
        caps = mgr.remaining_caps()
        if caps.any():
            emitted = np.asarray(
                [_emitted_row(r, i) if caps[i] > 0 else [-1] * EMIT_W
                 for i in range(n)], np.int32)
            mgr.record_emitted(emitted)
        else:
            mgr.tick()
    mgr.retire_done()
    return reqs, events


def _legacy_events(n, trace, rounds):
    """Independent reference of the PRE-PR manager: per-server FIFO
    queues filled directly at submit time, retire-then-fill each round,
    same deterministic emissions.  Returns admission events."""
    queues = [deque() for _ in range(n)]
    active = [None] * n            # [remaining, eos_token, done, trace_idx]
    events, idx = [], 0
    for r in range(rounds):
        while idx < len(trace) and trace[idx][0] <= r:
            _, srv, _, mn, eos = trace[idx]
            queues[srv].append([mn, eos, False, idx])
            idx += 1
        for i in range(n):
            if active[i] is not None and active[i][2]:
                active[i] = None
        for i in range(n):
            if active[i] is None and queues[i]:
                active[i] = queues[i].popleft()
                events.append((r, i, active[i][3]))
        if any(a is not None and not a[2] for a in active):
            for i in range(n):
                a = active[i]
                if a is None or a[2]:
                    continue
                toks = [t for t in _emitted_row(r, i) if t >= 0]
                if a[1] >= 0 and a[1] in toks:
                    toks = toks[: toks.index(a[1]) + 1]
                take = toks[: a[0]]
                a[0] -= len(take)
                if a[0] == 0 or (a[1] >= 0 and a[1] in take):
                    a[2] = True
    return events


def _assert_conserved(mgr, reqs):
    seen = [r.request_id for r in mgr.completed] \
        + [r.request_id for r in mgr.active if r is not None] \
        + [r.request_id for q in mgr.queues for r in q] \
        + [r.request_id for r in mgr.arrivals]
    assert sorted(seen) == sorted(r.request_id for r in reqs), \
        "request lost or duplicated"


# -- manager-level properties ----------------------------------------------

class TestPlacementProperties:
    @sweep(cases=20, seed=50)
    def test_conservation_every_policy(self, draw):
        n = draw.integers(2, 4)
        trace = _trace(draw, n, draw.integers(3, 12), 8)
        for policy in ("static", "jsq", "goodput"):
            mgr = RequestManager(n, placement=policy)
            reqs, _ = _drive(mgr, trace, rounds=30)
            _assert_conserved(mgr, reqs)
            st = mgr.stats()
            assert st["completed"] + st["queued"] \
                + sum(r is not None for r in mgr.active) == len(reqs)

    @sweep(cases=20, seed=51)
    def test_static_reproduces_legacy_fifo_order(self, draw):
        n = draw.integers(2, 5)
        trace = _trace(draw, n, draw.integers(4, 14), 10)
        mgr = RequestManager(n, placement="static")
        _, events = _drive(mgr, trace, rounds=40)
        assert events == _legacy_events(n, trace, 40)

    @sweep(cases=20, seed=52)
    def test_jsq_never_strictly_worse(self, draw):
        n = draw.integers(2, 5)
        trace = _trace(draw, n, draw.integers(4, 14), 8)
        spy = _Spy(JSQPlacement())
        mgr = RequestManager(n, placement=spy)
        reqs, _ = _drive(mgr, trace, rounds=30)
        _assert_conserved(mgr, reqs)
        assert spy.log, "no placement decisions recorded"
        for _, backlog, choice in spy.log:
            assert backlog[choice] == backlog.min(), \
                f"jsq placed on backlog {backlog[choice]} with " \
                f"{backlog.min()} available ({backlog})"

    @sweep(cases=20, seed=53)
    def test_goodput_cold_falls_back_to_jsq(self, draw):
        n = draw.integers(2, 5)
        trace = _trace(draw, n, draw.integers(4, 14), 8)
        alpha_init = 0.5

        def cold_view(mgr):
            return PlacementView(queue_load=mgr.queue_load(),
                                 active_remaining=mgr.remaining_caps(),
                                 alpha_hat=np.full((n,), alpha_init,
                                                   np.float32),
                                 alpha_init=alpha_init)

        events = {}
        for policy in ("jsq", "goodput"):
            mgr = RequestManager(n, placement=policy)
            _, events[policy] = _drive(mgr, trace, rounds=30,
                                       view_fn=cold_view)
        assert events["goodput"] == events["jsq"]

    def test_goodput_warm_prefers_high_alpha(self):
        """With distinct estimates and equal backlogs, goodput routes to
        the highest-alpha server (most expected accepted tokens/round)."""
        view = PlacementView(queue_load=np.zeros(3, np.int64),
                             active_remaining=np.zeros(3, np.int32),
                             alpha_hat=np.asarray([0.2, 0.9, 0.6],
                                                  np.float32),
                             alpha_init=0.5, s_max=4)
        req = Request(prompt=np.zeros(4, np.int32), max_new_tokens=5)
        assert GoodputPlacement().place(req, view) == 1

    @sweep(cases=15, seed=54)
    def test_pool_precheck_defers_not_raises(self, draw):
        """free_blocks too small for any prompt: every policy defers every
        admission (PoolExhaustedError-free), requests age honestly; once
        the pool recovers the whole trace drains."""
        n = draw.integers(2, 4)
        trace = _trace(draw, n, draw.integers(3, 8), 5, min_prompt=5)
        recover = 12

        def gated_view(free):
            def f(mgr):
                return PlacementView(queue_load=mgr.queue_load(),
                                     active_remaining=mgr.remaining_caps(),
                                     free_blocks=free(mgr),
                                     block_size=4)
            return f

        for policy in ("static", "jsq", "goodput"):
            mgr = RequestManager(n, placement=policy)
            reqs, events = _drive(
                mgr, trace, rounds=40,
                view_fn=gated_view(lambda m: 0 if m.round < recover
                                   else 10_000))
            _assert_conserved(mgr, reqs)
            assert all(r >= recover for r, _, _ in events), \
                "admitted through an exhausted pool"
            assert len(events) == len(reqs)   # drained after recovery

    def test_never_fitting_prompt_raises_not_livelocks(self):
        """Deferral is only for TEMPORARY pool pressure: a prompt larger
        than the whole pool can never be seated by waiting, so the gate
        raises ``PoolExhaustedError`` instead of deferring forever."""
        from repro.serving.kv_cache import PoolExhaustedError
        mgr = RequestManager(1)
        mgr.submit(0, Request(prompt=np.zeros(40, np.int32),
                              max_new_tokens=2))
        view = lambda free: PlacementView(
            queue_load=mgr.queue_load(),
            active_remaining=mgr.remaining_caps(),
            free_blocks=free, total_blocks=2, block_size=4)
        with pytest.raises(PoolExhaustedError):   # needs 10 of 2 blocks
            mgr.admit(view(2))

    def test_busy_choice_does_not_idle_free_servers(self):
        """A warm goodput head may hold out for a busy fast server; the
        free slow server must still seat the NEXT (younger, non-head)
        arrival that round — and removing that non-head from the global
        deque must not trip numpy-prompt equality."""
        mgr = RequestManager(2, placement="goodput")
        blocker = Request(prompt=np.zeros(4, np.int32), max_new_tokens=20)
        mgr.submit(None, blocker)
        view = lambda a: PlacementView(
            queue_load=mgr.queue_load(),
            active_remaining=mgr.remaining_caps(),
            alpha_hat=np.asarray(a, np.float32), alpha_init=0.5, s_max=6)
        assert mgr.admit(view([0.95, 0.05])) == [0]   # best server busy now
        elder = Request(prompt=np.zeros(4, np.int32), max_new_tokens=30)
        younger = Request(prompt=np.zeros(4, np.int32), max_new_tokens=4)
        mgr.submit(None, elder)
        mgr.tick()
        mgr.submit(None, younger)
        # elder (long budget) bets on the busy fast server and waits;
        # younger (short budget) prefers the free slow server and seats
        fresh = mgr.admit(view([0.95, 0.05]))
        assert fresh == [1]
        assert mgr.active[1] is younger
        assert list(mgr.arrivals) == [elder]

    def test_deferred_elder_not_starved_by_younger(self):
        """Head-of-line fairness under pool pressure: once the oldest
        waiting head defers for lack of blocks, a younger head on another
        server must not snatch the freed blocks that round."""
        mgr = RequestManager(2, placement="static")
        big = Request(prompt=np.zeros(30, np.int32), max_new_tokens=2)
        small = Request(prompt=np.zeros(6, np.int32), max_new_tokens=2)
        mgr.submit(0, big)
        mgr.tick()
        mgr.submit(1, small)      # younger, needs fewer blocks
        view = lambda free: PlacementView(
            queue_load=mgr.queue_load(),
            active_remaining=mgr.remaining_caps(),
            free_blocks=free, total_blocks=64, block_size=4, s_max=2)
        # big needs blocks_for(29+3)=8; small blocks_for(5+3)=2
        assert mgr.admit(view(4)) == []     # big defers -> small blocked too
        assert mgr.admit(view(10)) == [0, 1]   # both fit once blocks free

    def test_queue_wait_aging_honest(self):
        """A queued-behind request ages every round (emission rounds AND
        idle ticks), and its final wait equals admit - arrival."""
        mgr = RequestManager(1)
        first = Request(prompt=np.zeros(2, np.int32), max_new_tokens=6)
        second = Request(prompt=np.zeros(2, np.int32), max_new_tokens=2)
        mgr.submit(0, first)
        mgr.submit(0, second)
        mgr.admit()
        waited = 0
        while not first.done:
            mgr.record_emitted(np.asarray([[7, 8, -1]], np.int32))
            waited += 1
            assert second.queue_wait == waited
            assert mgr.stats()["queue_wait_ticks"][second.request_id] \
                == waited
        mgr.admit()
        assert second.queue_wait == second.admit_round - second.arrival_round

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            make_placement("round-robin")
        with pytest.raises(ValueError):
            RequestManager(2, placement="nope")


@pytest.mark.slow
class TestPlacementPropertiesLong:
    """The long random-trace sweeps (same invariants, bigger space)."""

    @sweep(cases=150, seed=60)
    def test_long_conservation_and_fifo(self, draw):
        n = draw.integers(2, 6)
        trace = _trace(draw, n, draw.integers(5, 25), 15)
        for policy in ("static", "jsq", "goodput"):
            mgr = RequestManager(n, placement=policy)
            reqs, events = _drive(mgr, trace, rounds=60)
            _assert_conserved(mgr, reqs)
            if policy == "static":
                assert events == _legacy_events(n, trace, 60)


# -- engine-level pins -------------------------------------------------------

class LegacyDirectManager(RequestManager):
    """The pre-PR admission path: ``submit`` appends straight to the
    per-server FIFO queue — no global arrival queue, no placement step.
    Serving through it reproduces the old engine's admission behaviour
    bit-for-bit, which is what ``placement="static"`` must match."""

    def submit(self, server, request):
        request.arrival_round = self.round
        request.server_hint = int(server)
        self.queues[server].append(request)


@pytest.mark.slow
class TestStaticEquivalenceTrace:
    """Satellite: the ACCEPTANCE mixed admit/retire/EOS workload under
    ``placement="static"`` emits byte-identical accepted-token sequences
    to the pre-PR engine (legacy direct-submit manager), for paged and
    static caches and both attn backends."""

    @pytest.mark.parametrize("paged,backend", [
        (False, "jnp"), (True, "jnp"), (False, "kernel"), (True, "kernel")])
    def test_static_matches_legacy_fifo(self, mixed_trace, paged, backend):
        legacy = mixed_trace(paged_kv=paged, attn_backend=backend,
                             manager=LegacyDirectManager(2))
        new = mixed_trace(paged_kv=paged, attn_backend=backend,
                          placement="static")
        assert conftest.generated_seqs(new) == conftest.generated_seqs(legacy)


@pytest.mark.slow
class TestFairnessRegression:
    """Satellite: on a 2-fast/2-slow alpha setup with arrivals skewed onto
    the slow servers, goodput placement must not be less fair than static
    (Jain's index over per-server served tokens) and no server starves."""

    N = 4

    def _workload(self):
        rng = np.random.default_rng(17)
        return [(int(rng.integers(0, 6)), 2 + (j % 2),
                 Request(prompt=rng.integers(
                     1, conftest.MIXED_TRACE_VOCAB, size=6).astype(np.int32),
                     max_new_tokens=4)) for j in range(10)]

    def test_goodput_jain_ge_static(self, serve_pair):
        dm, tm, dp, tp = serve_pair
        jains, reps = {}, {}
        for placement in ("static", "goodput"):
            eng = GoodSpeedEngine(
                draft_model=dm, target_model=tm, n_servers=self.N, C=10,
                s_max=4, cache_len=128, placement=placement,
                draft_temps=(1.0, 1.0, 3.5, 3.5))   # 2 fast / 2 slow
            rep = eng.serve_requests(jax.random.PRNGKey(9),
                                     self._workload(), dp, tp, rounds=50)
            assert rep["summary"]["completed"] == 10
            per_server = np.zeros(self.N)
            for r in rep["requests"]:
                per_server[r["server"]] += r["tokens"]
            jains[placement], reps[placement] = jain(per_server), rep
        assert jains["goodput"] >= jains["static"], jains
        admitted = reps["goodput"]["summary"]["per_server_admitted"]
        assert all(a >= 1 for a in admitted), \
            f"server starved under goodput placement: {admitted}"
