"""Reproduce the paper's Figures 2-4 (CSV output; no matplotlib offline).

Writes experiments/fig{2,3,4}.csv with the per-round traces so the paper's
plots can be regenerated:
  fig2: round, estimated goodput (MA-10), realized goodput (MA-10), sigma
  fig3: policy, receive_s, verify_s, send_s, total_s
  fig4: round, U_goodspeed, U_fixed, U_random

Run:  PYTHONPATH=src python examples/paper_experiments.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.core.utility import UtilitySpec
from repro.data.pipeline import make_workload

N, C, ROUNDS = 8, 20, 1000
OUT = "experiments"


def _sim(policy, alphas, beta=0.1):
    coord = Coordinator(n=N, C=C, policy=policy,
                        estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                                   beta=StepSchedule(beta)))
    _, logs = coord.simulate_analytic(jax.random.PRNGKey(7), alphas)
    return logs


def ma(x, w=10):
    c = np.cumsum(np.insert(x, 0, 0.0, axis=0), axis=0)
    return (c[w:] - c[:-w]) / w


def main():
    os.makedirs(OUT, exist_ok=True)
    _, alphas = make_workload(N, 32000, ROUNDS)

    # Fig 2: estimation fidelity (beta=0.5 as in the paper's plots)
    logs = _sim("goodspeed", alphas, beta=0.5)
    est = ma(np.asarray(logs.goodput_est).sum(1))
    real = ma(np.asarray(logs.realized).sum(1))
    sig = np.sqrt(np.maximum(ma((np.asarray(logs.realized).sum(1)
                                 - np.asarray(logs.goodput_est).sum(1))**2),
                             1e-12))
    with open(f"{OUT}/fig2.csv", "w") as f:
        f.write("round,estimated_ma,realized_ma,sigma\n")
        for t in range(len(est)):
            f.write(f"{t},{est[t]:.4f},{real[t]:.4f},{sig[t]:.4f}\n")
    print(f"fig2.csv: MAE={np.abs(est - real).mean():.3f} "
          f"corr={np.corrcoef(est, real)[0, 1]:.3f}")

    # Fig 3: time distribution
    with open(f"{OUT}/fig3.csv", "w") as f:
        f.write("policy,receive_s,verify_s,send_s,total_s\n")
        for pol in ("goodspeed", "fixed", "random"):
            w = np.asarray(_sim(pol, alphas).wall).mean(0)
            f.write(f"{pol},{w[1]:.5f},{w[2]:.5f},{w[3]:.5f},{w[0]:.5f}\n")
            print(f"fig3 {pol:10s} total={w[0]*1e3:.2f}ms "
                  f"(recv {100*w[1]/w[0]:.0f}% verify {100*w[2]/w[0]:.0f}% "
                  f"send {100*w[3]/w[0]:.1f}%)")

    # Fig 4: utility convergence
    u = UtilitySpec(alpha=1.0)
    trajs = {}
    for pol in ("goodspeed", "fixed", "random"):
        realized = np.asarray(_sim(pol, alphas).realized)
        csum = np.cumsum(realized, 0) / np.arange(1, ROUNDS + 1)[:, None]
        trajs[pol] = np.array([float(u.value(jnp.asarray(r)))
                               for r in csum])
    with open(f"{OUT}/fig4.csv", "w") as f:
        f.write("round,U_goodspeed,U_fixed,U_random\n")
        for t in range(ROUNDS):
            f.write(f"{t},{trajs['goodspeed'][t]:.4f},"
                    f"{trajs['fixed'][t]:.4f},{trajs['random'][t]:.4f}\n")
    print(f"fig4: final U goodspeed={trajs['goodspeed'][-1]:.3f} "
          f"fixed={trajs['fixed'][-1]:.3f} random={trajs['random'][-1]:.3f}")


if __name__ == "__main__":
    main()
