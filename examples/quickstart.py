"""Quickstart: the GoodSpeed scheduler in 60 seconds.

Builds the gradient scheduler, simulates 300 rounds of the Algorithm-1
loop against a synthetic 8-server edge workload, and prints how the
allocation adapts to heterogeneous acceptance rates.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Coordinator, GoodputEstimator, StepSchedule,
                        expected_goodput, optimal_goodput, solve_threshold)
from repro.data.pipeline import PAPER_DATASETS, make_workload

N, C, ROUNDS = 8, 20, 300


def main():
    # --- one-shot: solve GOODSPEED-SCHED directly -------------------------
    alpha = jnp.asarray([0.9, 0.75, 0.6, 0.45, 0.3, 0.85, 0.5, 0.7])
    weights = 1.0 / expected_goodput(jnp.full((N,), 2.0), alpha)  # ~1/x
    out = solve_threshold(alpha, weights, C)
    print("one-shot GOODSPEED-SCHED allocation")
    print("  alpha:", np.round(np.asarray(alpha), 2))
    print("  S*:   ", np.asarray(out.S), " (sum <=", C, ")")

    # --- closed loop over a drifting workload ------------------------------
    domains, alphas = make_workload(N, 32000, ROUNDS)
    coord = Coordinator(n=N, C=C, policy="goodspeed",
                        estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                                   beta=StepSchedule(0.1)))
    _, logs = coord.simulate_analytic(jax.random.PRNGKey(0), alphas)

    print(f"\n{ROUNDS} rounds against the paper's 8 synthetic datasets:")
    print(f"  {'dataset':18s} {'true a':>7s} {'est a':>7s} "
          f"{'S(final)':>8s} {'goodput':>8s}")
    for i in range(N):
        print(f"  {domains[i].name:18s} {float(alphas[-1, i]):7.2f} "
              f"{float(logs.alpha_hat[-1, i]):7.2f} "
              f"{int(logs.S[-1, i]):8d} "
              f"{float(logs.goodput_est[-1, i]):8.2f}")

    _, x_star = optimal_goodput(alphas[-1], C)
    print(f"\n  utility U(X^beta) = {float(logs.utility[-1]):.3f}"
          f"   (fluid optimum U(x*) = "
          f"{float(jnp.sum(jnp.log(x_star))):.3f})")


if __name__ == "__main__":
    main()
