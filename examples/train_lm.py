"""Train a ~100M-parameter draft model on the synthetic workload.

Demonstrates the training substrate end-to-end: config -> Model -> AdamW ->
jit'd train_step -> checkpoint save/restore.  Loss should fall from
~ln(vocab) toward the Zipf-mixture entropy.  (Training better draft models
raises alpha_i, which is exactly what GoodSpeed's scheduler rewards.)

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import token_stream
from repro.models import Model
from repro.training import checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_state import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/goodspeed_draft_ckpt")
    args = ap.parse_args()

    # ~100M-param qwen3-family draft model
    cfg = get_reduced("qwen3-8b", num_layers=8, d_model=512, num_heads=8,
                      num_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab_size=8192)
    model = Model(cfg)
    print(f"model: {cfg.name}-reduced  params~{cfg.param_count()/1e6:.1f}M")

    opt = AdamW(learning_rate=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, remat=False))

    losses = []
    t0 = time.time()
    for i, batch in enumerate(token_stream(cfg.vocab_size, args.batch,
                                           args.seq, args.steps)):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss={losses[-1]:.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"({(time.time() - t0):.0f}s)")
    assert losses[-1] < losses[0], "loss must decrease"

    checkpoint.save(args.ckpt, state.params, {"step": args.steps,
                                              "config": cfg.name})
    restored = checkpoint.restore(args.ckpt, state.params)
    leaves_equal = all(
        bool((a == b).all()) for a, b in zip(
            jax.tree.leaves(state.params), jax.tree.leaves(restored)))
    print(f"checkpoint round-trip OK: {leaves_equal}  -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
