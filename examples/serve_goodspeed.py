"""End-to-end GoodSpeed serving driver (the paper's deployment, miniature).

N draft servers each run a REAL (reduced-dim) draft transformer; the
verification server runs a larger target transformer.  Every round executes
Algorithm 1 with actual logits: autoregressive drafting, batched rejection-
sampling verification, Eq.3/Eq.4 estimator updates and GOODSPEED-SCHED
allocation.  Compares goodspeed / fixed / random policies, then drains a
multi-user request workload through the continuous-batching lifecycle loop
(``serve_requests``): FIFO admission per server, per-row cache re-prefill
on admission, completion-aware scheduling, EOS/cap termination.

``--churn`` additionally scripts server churn against the drain (crash +
rejoin, a straggler window, a dropped chunk) with a per-round verify
``--deadline``: late chunks are discarded exactly, a server that keeps
missing goes DOWN, and its in-flight requests migrate back to the global
queue with their committed tokens preserved (``repro.serving.faults``).

Run:  PYTHONPATH=src python examples/serve_goodspeed.py [--rounds 30]
      PYTHONPATH=src python examples/serve_goodspeed.py \\
          --churn --placement goodput --lanes 2
"""
import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import PAPER_DATASETS, SyntheticDomain
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.request import Request

N = 4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--C", type=int, default=12)
    ap.add_argument("--attn-backend", choices=("jnp", "kernel"),
                    default="jnp", help="serving attention backend: jnp "
                    "core or the Pallas kernel packages (auto-fallback "
                    "to fused jnp refs off-TPU)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-pool KV caches (admission block reuse)")
    ap.add_argument("--placement", choices=("static", "jsq", "goodput"),
                    default="static", help="request placement at admission "
                    "(serve_requests): submitted per-server affinity, "
                    "join-shortest-queue, or alpha_hat/KV-aware goodput "
                    "placement")
    ap.add_argument("--lanes", type=int, default=1,
                    help="draft lanes: concurrent requests per draft "
                    "server (the serve_requests batch axis becomes "
                    "n_servers * lanes, server-major)")
    ap.add_argument("--overlap", action="store_true",
                    help="async round graph: dispatch the next round's "
                    "draft-ahead while the verify chunk is in flight "
                    "(deferred reconcile discards the speculative tail; "
                    "emitted tokens are identical to the sync engine)")
    ap.add_argument("--churn", action="store_true",
                    help="inject server churn into the request drain: "
                    "crash server 1 mid-drain (its requests migrate), a "
                    "20x straggler window on server 2, one dropped chunk "
                    "on server 3, then a rejoin — with verify deadlines "
                    "and the healthy/suspect/down tracker mitigating")
    ap.add_argument("--deadline", type=float, default=0.12,
                    help="per-round verify deadline in seconds under "
                    "--churn: a chunk arriving later is discarded for the "
                    "round (that server accepts zero tokens; caches roll "
                    "back exactly)")
    args = ap.parse_args()

    vocab = 256
    draft = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              d_ff=128, vocab_size=vocab))
    target = Model(get_reduced("qwen3-8b", num_layers=2, d_model=128,
                               num_heads=4, num_kv_heads=2, head_dim=32,
                               d_ff=256, vocab_size=vocab))
    dp = draft.init(jax.random.PRNGKey(0))
    tp = target.init(jax.random.PRNGKey(1))

    rng = np.random.default_rng(0)
    prompts = [SyntheticDomain(PAPER_DATASETS[i], vocab, i)
               .sample_prompt(rng)[:12] for i in range(N)]
    temps = (1.0, 1.4, 2.0, 2.8)   # heterogeneous draft/target alignment

    for policy in ("goodspeed", "fixed", "random"):
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=args.C, s_max=6, cache_len=512,
                              policy=policy, draft_temps=temps,
                              attn_backend=args.attn_backend,
                              paged_kv=args.paged_kv)
        hist = eng.serve(jax.random.PRNGKey(2), prompts, dp, tp,
                         rounds=args.rounds)
        tok = np.mean([h.realized.sum() for h in hist])
        util = hist[-1].utility
        wall = np.mean([h.wall[0] for h in hist])
        print(f"{policy:10s} tokens/round={tok:6.2f}  U(X)={util:7.3f}  "
              f"wall/round={wall * 1e3:6.1f}ms  "
              f"alpha_hat={np.round(hist[-1].alpha_hat, 2)}  "
              f"S(final)={hist[-1].S}")

    # ---- multi-user request lifecycle (continuous batching) ---------------
    reqs = [Request(prompt=SyntheticDomain(PAPER_DATASETS[j % 8], vocab, 100 + j)
                    .sample_prompt(rng)[:16],
                    max_new_tokens=int(rng.integers(8, 16)))
            for j in range(3 * N)]
    eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                          n_servers=N, C=args.C, s_max=6, cache_len=512,
                          draft_temps=temps,
                          attn_backend=args.attn_backend,
                          paged_kv=args.paged_kv,
                          placement=args.placement,
                          lanes=args.lanes,
                          overlap=args.overlap)
    plan = None
    if args.churn:
        plan = FaultPlan(events=(
            FaultEvent(round=6, kind="crash", server=1),
            FaultEvent(round=18, kind="rejoin", server=1),
            FaultEvent(round=4, kind="slowdown", server=2, factor=20.0,
                       duration=8),
            FaultEvent(round=13, kind="rejoin", server=2),
            FaultEvent(round=8, kind="drop", server=3),
        ), deadline=args.deadline, k_down=2)
    rep = eng.serve_requests(jax.random.PRNGKey(3), reqs, dp, tp,
                             rounds=8 * args.rounds, faults=plan)
    s = rep["summary"]
    print(f"\nserve_requests[{args.placement}, lanes={args.lanes}"
          f"{', overlap' if args.overlap else ''}"
          f"{', churn' if args.churn else ''}]: "
          f"{s['completed']}/{len(reqs)} requests in "
          f"{s['rounds_run']} rounds  tokens/round={s['tokens_per_round']:.2f}  "
          f"mean latency={s['mean_latency_rounds']:.1f} rounds  "
          f"mean queue delay={s['mean_queue_delay_rounds']:.1f} rounds  "
          f"admitted/server={s['per_server_admitted']}")
    if args.churn:
        f = s["faults"]
        print(f"churn: migrations={s['migrations']}  "
              f"lost={s['requests_lost']}  deadline misses={f['misses']}  "
              f"down events={f['down_events']}  "
              f"rejoins={f['rejoin_events']}  final status={f['status']}")


if __name__ == "__main__":
    main()
