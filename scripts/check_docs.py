"""Docs/README consistency check: fail when documentation names modules,
attributes, or files that no longer exist.

Checked, across README.md and docs/*.md:
  * backticked dotted references (`repro.serving.engine.GoodSpeedEngine`)
    must import / resolve attribute-by-attribute;
  * backticked file paths (`benchmarks/serve_requests.py`) must exist in
    the repo (directly or uniquely by basename, so tables can shorten
    `docs/ARCHITECTURE.md` to `ARCHITECTURE.md`);
  * inside fenced code blocks: ``python -m pkg.mod`` targets must import
    and path-like tokens ending in .py/.md must exist.

Run: ``python -m scripts.check_docs`` (or ``make docs-check``).  Also
wired into tier-1 as ``tests/test_docs.py``.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_DOTTED = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")
_TICKED_PATH = re.compile(r"`([A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md))`")
_FENCE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
_PY_M = re.compile(r"python -m ([A-Za-z_][A-Za-z0-9_.]*)")
_BLOCK_PATH = re.compile(r"(?:^|[\s=(])([A-Za-z0-9_][A-Za-z0-9_./-]*"
                         r"\.(?:py|md))")


def _doc_files() -> list[pathlib.Path]:
    docs = sorted((ROOT / "docs").glob("*.md")) if (ROOT / "docs").is_dir() \
        else []
    readme = ROOT / "README.md"
    return ([readme] if readme.exists() else []) + docs


def _importable(dotted: str) -> bool:
    """True if ``dotted`` resolves to a module, or to an attribute chain
    hanging off the longest importable module prefix."""
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return False


# roots searched for basename-only references; deliberately NOT the whole
# tree, so a stray same-named file in a vendored/experiment directory
# cannot mask a renamed source file
_BASENAME_ROOTS = ("src", "docs", "scripts", "benchmarks", "examples",
                   "tests")


def _path_exists(p: str) -> bool:
    if (ROOT / p).exists():
        return True
    # allow basename-only references (e.g. `scheduler.py` in a table row
    # whose Path column already names src/repro/core/) within the known
    # source roots
    if "/" not in p:
        return any(next((ROOT / r).glob(f"**/{p}"), None) is not None
                   for r in _BASENAME_ROOTS if (ROOT / r).is_dir())
    return False


def collect_errors() -> list[str]:
    for path in (str(ROOT / "src"), str(ROOT)):
        if path not in sys.path:
            sys.path.insert(0, path)
    errors: list[str] = []
    for doc in _doc_files():
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for dotted in sorted(set(_DOTTED.findall(text))):
            if not _importable(dotted):
                errors.append(f"{rel}: unresolvable reference "
                              f"`{dotted}`")
        for p in sorted(set(_TICKED_PATH.findall(text))):
            if not _path_exists(p):
                errors.append(f"{rel}: missing file `{p}`")
        for block in _FENCE.findall(text):
            for mod in sorted(set(_PY_M.findall(block))):
                if not _importable(mod):
                    errors.append(f"{rel}: code block runs "
                                  f"`python -m {mod}` but it does not "
                                  f"import")
            for p in sorted(set(_BLOCK_PATH.findall(block))):
                if not _path_exists(p):
                    errors.append(f"{rel}: code block references "
                                  f"missing file `{p}`")
    return errors


def main() -> int:
    docs = _doc_files()
    errors = collect_errors()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: {len(docs)} docs OK "
              f"({', '.join(str(d.relative_to(ROOT)) for d in docs)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
