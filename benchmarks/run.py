"""Benchmark harness — one module per paper table/figure.

  fig2  goodput_estimation   — estimated vs realized goodput fidelity
  fig3  time_distribution    — wall-time decomposition vs baselines
  fig4  utility_convergence  — U(x_bar) convergence + gap to fluid optimum
  tblI  scheduler_bench      — GOODSPEED-SCHED solver timings + C* budgets
  e2e   engine_e2e           — real-model Algorithm-1 rounds
  serve serve_requests       — request throughput + completion latency
                               under Poisson-ish arrivals (continuous
                               batching), swept over attn_backend, plus
                               the skewed-arrival placement-policy sweep
                               (static/jsq/goodput: goodput, queue-wait
                               percentiles, Jain fairness); writes the
                               BENCH_serve.json perf baseline
  perf  paged_decode_bench   — paged decode attention: block-table-native
                               kernel path vs the paged_view gather path
  ablations                  — utility-family / budget / top-k sweeps
  roofline                   — terms from the dry-run artifacts (§Roofline)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (ablations, engine_e2e, goodput_estimation,
                            paged_decode_bench, roofline, scheduler_bench,
                            serve_requests, time_distribution,
                            utility_convergence)
    # paged_decode_bench runs BEFORE any engine module: its µs-scale
    # numbers (cached and embedded into BENCH_serve.json by
    # serve_requests) are noise-sensitive to leftover compiled state
    modules = [goodput_estimation, time_distribution, utility_convergence,
               scheduler_bench, paged_decode_bench, engine_e2e,
               serve_requests, ablations, roofline]
    print("name,us_per_call,derived")
    failed = 0
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
