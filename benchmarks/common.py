"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn, *args, iters: int = 5, warmup: int = 2, **kwargs):
    """Median wall time in microseconds (post-warmup, block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times)), out


def jain(x: np.ndarray) -> float:
    """Jain's fairness index over per-server allocations: 1 = perfectly
    even, 1/N = one server takes everything."""
    x = np.asarray(x, np.float64)
    return float(x.sum() ** 2 / (len(x) * (x ** 2).sum() + 1e-12))


def moving_average(x: np.ndarray, w: int = 10) -> np.ndarray:
    if len(x) < w:
        return x
    c = np.cumsum(np.insert(x, 0, 0.0, axis=0), axis=0)
    return (c[w:] - c[:-w]) / w
