"""Paper Fig. 2 — estimated vs realized goodput fidelity.

Runs the GoodSpeed round loop (8 clients, paper's non-stationary dataset
mix) and reports, after a moving-average filter of window 10 as in the
paper: the MAE between X^beta(t) and realized x(t), their correlation, and
the fraction of realized-goodput points inside the +/-1 sigma band.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import moving_average, time_call
from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.data.pipeline import make_workload

N, C, ROUNDS = 8, 20, 1000


def run():
    _, alphas = make_workload(N, 32000, ROUNDS)
    coord = Coordinator(
        n=N, C=C, policy="goodspeed",
        estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                   beta=StepSchedule(0.5)))  # paper beta=0.5
    us, (_, logs) = time_call(
        lambda: coord.simulate_analytic(jax.random.PRNGKey(0), alphas),
        iters=3, warmup=1)

    est = np.asarray(logs.goodput_est)     # X^beta(t) [T, N]
    real = np.asarray(logs.realized)       # x(t)
    est_ma = moving_average(est, 10)
    real_ma = moving_average(real, 10)
    mae = float(np.mean(np.abs(est_ma - real_ma)))
    corr = float(np.corrcoef(est_ma.mean(1), real_ma.mean(1))[0, 1])
    # sigma band coverage (sqrt of MA variance, as the paper plots)
    var_ma = moving_average((real - est) ** 2, 10)
    sigma = np.sqrt(np.maximum(var_ma, 1e-12))
    inside = float(np.mean(np.abs(real_ma - est_ma) <= sigma + 1e-9))
    return [
        ("fig2_goodput_estimation_mae", us / ROUNDS, round(mae, 4)),
        ("fig2_goodput_estimation_corr", us / ROUNDS, round(corr, 4)),
        ("fig2_sigma_band_coverage", us / ROUNDS, round(inside, 4)),
    ]
