"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
  memory term     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
  collective term = collective_bytes_per_device / ICI link bw   (~50e9 B/s)

HLO numbers come from the calibrated dry-run records (cost_analysis is
per-device under SPMD; scan bodies were calibrated via unrolled compiles).
Also derives MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.core.budget import V5E

def _default_dir():
    # prefer the optimized artifacts when present; baseline is preserved in
    # experiments/dryrun_baseline (see EXPERIMENTS.md §Perf)
    for d in ("experiments/dryrun_opt", "experiments/dryrun",
              "experiments/dryrun_baseline"):
        if os.path.isdir(d) and os.listdir(d):
            return d
    return "experiments/dryrun"


DRYRUN_DIR = os.environ.get("DRYRUN_DIR") or _default_dir()


def model_flops_per_device(arch: str, shape: str, devices: int) -> float:
    cfg = get_config(arch)
    seq, batch, kind = INPUT_SHAPES[shape]
    n = cfg.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n * tokens / devices
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n * tokens / devices
    # decode: one token per sequence
    return 2.0 * n * batch / devices


def analyze(record: dict) -> dict:
    dev = record["devices"]
    flops = record["flops"]
    bytes_acc = record["bytes_accessed"]
    coll = record["collective_total_bytes"]
    t_compute = flops / V5E.peak_flops
    t_memory = bytes_acc / V5E.hbm_bw
    t_coll = coll / V5E.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(record["arch"], record["shape"], dev)
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.removesuffix("_s"),
        "model_flops_per_device": mf,
        "useful_ratio": round(mf / flops, 4) if flops else None,
        "bound_time_s": round(max(terms.values()), 6),
    }


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        a = analyze(rec)
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        rows.append((f"roofline_{tag}_bound_{a['dominant']}", 0.0,
                     a["bound_time_s"]))
    if not rows:
        rows.append(("roofline_no_dryrun_artifacts_found", 0.0, 0))
    return rows


def full_table() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        out.append({"arch": rec["arch"], "shape": rec["shape"],
                    "mesh": rec["mesh"], **analyze(rec)})
    return out
