"""Request-lifecycle serving benchmark: continuous batching under load.

Drives ``GoodSpeedEngine.serve_requests`` with a Poisson-ish arrival
process (deterministic rng): K requests arrive over the first half of the
horizon, exponential-ish inter-arrival gaps, round-robin server affinity,
heterogeneous per-request token budgets.  Reports request throughput
(completions and tokens per round) and mean completion latency (arrival ->
finish, in rounds) for the goodspeed policy vs the fixed-S baseline.

Also measures single-request ADMISSION cost vs batch size (B in {4, 16,
64}): the static path re-prefills the full batch and row-merges, so its
cost grows with B; the paged path prefills only the admitted row into the
shared block pool, so its cost is ~flat in B.

The serve sweep carries an ``attn_backend`` dimension (jnp vs kernel, and
kernel x paged), and the whole run — per-round wall latency, realized
goodput (tokens/round), admission cost, and the paged-decode
gather-vs-block-native microbench (``benchmarks.paged_decode_bench``) —
is written to ``BENCH_serve.json`` at the repo root so future PRs have a
perf baseline to regress against.

The SKEWED scenario (``--scenario skewed``, also part of the full run)
stresses the global admission layer: Zipf-weighted server arrivals (most
requests hint the same server) against heterogeneous per-server draft
alignment (draft temperatures), swept over the placement policies
(static / jsq / goodput).  Per policy it records total accepted tokens,
completions, p50/p95 queue wait (from the manager's per-request
queue-wait ticks), and Jain's fairness index over per-server served
tokens, into the ``placement_skewed`` section of ``BENCH_serve.json``.

The HEAVY scenario (``--scenario heavy``, also part of the full run)
measures the draft-lane utilization win: an OVERSUBSCRIBED burst of
short requests (all arrivals in the first third of a fixed horizon, far
more requests than servers) swept over ``lanes`` in {1, 2, 4} at a
fixed verify budget.  With one lane per server a finished request
leaves its server idle until the next admission; with R lanes the
server keeps R requests in flight, so the same C is spent on live work
every round.  Per lane count it records total accepted tokens
(including in-flight partial progress at the horizon), completions,
p50/p95 queue wait, and Jain's index over per-server served tokens,
merged into the ``lanes_heavy`` section of ``BENCH_serve.json``
(read-modify-write: a single-scenario refresh keeps other baselines).

The OVERLAP scenario (``--scenario overlap``, also part of the full run)
serves the heavy burst with the synchronous composed round vs the
four-phase async round graph (``GoodSpeedEngine(overlap=True)``) and
records accepted tokens, simulated round time (overlap prices rounds as
max(receive_t, verify_{t-1}) + send) and measured wall-clock per round
into the ``overlap`` section; it also asserts the retrace telemetry —
no round phase compiles more than once per verify bucket.

The PREFIX scenario (``--scenario prefix``, also part of the full run)
measures refcounted prefix caching: a burst of requests that all share a
long system prompt (>= 75% of each prompt) with short unique suffixes,
served with ``prefix_cache=True`` vs the plain paged engine, plus a
single-request admission microbench against a registered shared prefix.
Sharing must cut the admission cost >= 2x (only the unique suffix is
prefilled; the shared blocks attach by refcount) WITHOUT changing the
accepted-token stream (the attached blocks hold bitwise identical K/V).
Records admission us on/off, serve tokens / completions / Jain on/off
and the index hit rate into the ``prefix_shared`` section of
``BENCH_serve.json``.

The CHURN scenario (``--scenario churn``, also part of the full run)
drains a workload through a scripted adversary (mid-drain crash +
rejoin, a 20x straggler window, an uplink-drop burst — see
``repro.serving.faults``) twice: once with the mitigations on (finite
verify deadline, health state machine, exact request migration) and
once as the no-mitigation baseline (infinite deadline, crashes destroy
seated requests' state).  It records accepted tokens, requests lost
(must be 0 mitigated), Jain's index over PER-REQUEST token counts, p95
queue wait and simulated round time into the ``churn`` section, and
asserts the mitigated run strictly beats the baseline on tokens and
fairness.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):    # plain-file invocation (PYTHONPATH=src
    # python benchmarks/serve_requests.py): make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from benchmarks.common import jain
from repro.configs import get_reduced
from repro.data.pipeline import PAPER_DATASETS, SyntheticDomain
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine
from repro.serving.request import Request

N, K, ROUNDS, VOCAB = 4, 16, 80, 256
# skewed-arrival scenario: heavier load, tighter horizon (a hot server
# cannot drain its backlog in time under static affinity)
SKEW_K, SKEW_ROUNDS, SKEW_ZIPF = 32, 48, 1.5
SKEW_TEMPS = (1.0, 1.3, 2.0, 2.8)     # heterogeneous per-server alpha
PLACEMENTS = ("static", "jsq", "goodput")
# heavy-traffic lane sweep: an oversubscribed burst of short requests on
# a FIXED horizon — the utilization gap lanes close is admission cadence,
# so requests are short (a one-lane server idles between completions)
HEAVY_K, HEAVY_ROUNDS = 80, 24
HEAVY_LANES = (1, 2, 4)
# prefix-sharing scenario: a long shared system prompt dominates every
# prompt (shared fraction >= 75%) so admission cost is suffix-bound when
# sharing is on; the serve burst mirrors the heavy scenario's cadence
PREFIX_K, PREFIX_ROUNDS = 24, 48
PREFIX_SYS_LEN, PREFIX_SUF_LEN = 96, 16          # serve workload prompts
# admission microbench sizes: the prompt must be long enough that the
# prefill chunk (quadratic attention) dominates fixed dispatch overhead
PREFIX_ADMIT_SHARED, PREFIX_ADMIT_SUF = 1984, 32
PREFIX_ADMIT_CACHE_LEN = 2048
# churn scenario: mid-drain crash + straggler + uplink drops against the
# mitigated engine (verify deadlines + health tracking + exact request
# migration) vs the no-mitigation baseline (infinite deadline, crashes
# destroy seated requests' state)
CHURN_K, CHURN_ROUNDS = 24, 72
CHURN_DEADLINE = 0.12
ADMIT_BATCHES = (4, 16, 64)
ADMIT_PROMPT_LEN = 96
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serve.json"
# (policy, attn_backend, paged_kv) serve configurations; the first two
# keep the historical row names (jnp backend) for baseline continuity
SERVE_CONFIGS = (
    ("goodspeed", "jnp", False),
    ("fixed", "jnp", False),
    ("goodspeed", "kernel", False),
    ("goodspeed", "kernel", True),
)


def _workload(seed: int = 0):
    """(arrival_round, server, Request) with exp-ish inter-arrival gaps."""
    rng = np.random.default_rng(seed)
    items, t = [], 0.0
    for j in range(K):
        t += rng.exponential(ROUNDS / (2.0 * K))
        dom = SyntheticDomain(PAPER_DATASETS[j % len(PAPER_DATASETS)],
                              VOCAB, j)
        req = Request(prompt=dom.sample_prompt(rng)[:16],
                      max_new_tokens=int(rng.integers(6, 14)))
        items.append((int(t), j % N, req))
    return items


def _skewed_workload(seed: int = 3):
    """Zipf-weighted server arrivals: P(server j) ~ 1/(j+1)^SKEW_ZIPF, so
    the fastest server is also the hottest — exactly the hot-spot the
    placement policies exist to dissolve.  All arrivals land in the first
    half of the horizon."""
    rng = np.random.default_rng(seed)
    w = 1.0 / (np.arange(N) + 1.0) ** SKEW_ZIPF
    w /= w.sum()
    items, t = [], 0.0
    for j in range(SKEW_K):
        t += rng.exponential(SKEW_ROUNDS / (2.0 * SKEW_K))
        dom = SyntheticDomain(PAPER_DATASETS[j % len(PAPER_DATASETS)],
                              VOCAB, 50 + j)
        req = Request(prompt=dom.sample_prompt(rng)[:16],
                      max_new_tokens=int(rng.integers(8, 16)))
        items.append((int(t), int(rng.choice(N, p=w)), req))
    return items


def _drain_metrics(rep):
    """(total_tokens, per_server_tokens, p50, p95): accepted tokens a
    fixed serving window actually delivered — INCLUDING partial progress
    of requests still in flight at the horizon — split per server, plus
    queue-wait percentiles from the manager's per-request wait ticks."""
    mgr, s = rep["manager"], rep["summary"]
    reqs = mgr.completed + [r for r in mgr.active if r is not None]
    per_server = np.zeros(N)
    for r in reqs:
        srv = r.placed_server if r.placed_server is not None \
            else r.server_hint
        per_server[srv] += len(r.generated)
    waits = np.asarray(sorted(s["queue_wait_ticks"].values()), np.float64)
    p50, p95 = (float(np.percentile(waits, 50)),
                float(np.percentile(waits, 95))) if len(waits) \
        else (0.0, 0.0)
    return sum(len(r.generated) for r in reqs), per_server, p50, p95


def skewed_scenario(draft, target, dp, tp):
    """(csv_rows, json_section): the placement-policy sweep under skewed
    arrivals and heterogeneous alpha."""
    rows, section = [], {}
    for placement in PLACEMENTS:
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=12, s_max=6, cache_len=256,
                              draft_temps=SKEW_TEMPS, paged_kv=True,
                              kv_block_size=16, placement=placement)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(6), _skewed_workload(),
                                 dp, tp, rounds=SKEW_ROUNDS)
        wall = time.perf_counter() - t0
        s = rep["summary"]
        total_tokens, per_server, p50, p95 = _drain_metrics(rep)
        fairness = round(jain(per_server), 4)
        rows.append((f"skewed_{placement}_total_accepted_tokens",
                     round(wall * 1e6 / max(1, s["rounds_run"]), 0),
                     total_tokens))
        rows.append((f"skewed_{placement}_jain_fairness", 0.0, fairness))
        rows.append((f"skewed_{placement}_p95_queue_wait_rounds", 0.0,
                     round(p95, 1)))
        section[placement] = {
            "total_accepted_tokens": total_tokens,
            "completed": s["completed"],
            "of_requests": SKEW_K,
            "jain_fairness": fairness,
            "p50_queue_wait_rounds": round(p50, 1),
            "p95_queue_wait_rounds": round(p95, 1),
            "per_server_tokens": per_server.astype(int).tolist(),
            "per_server_admitted": s["per_server_admitted"],
            "rounds_run": s["rounds_run"],
        }
    return rows, section


def _heavy_workload(seed: int = 5):
    """Oversubscribed burst: HEAVY_K short requests all arriving in the
    first third of the horizon, round-robin server hints."""
    rng = np.random.default_rng(seed)
    items, t = [], 0.0
    for j in range(HEAVY_K):
        t += rng.exponential(HEAVY_ROUNDS / (3.0 * HEAVY_K))
        dom = SyntheticDomain(PAPER_DATASETS[j % len(PAPER_DATASETS)],
                              VOCAB, 90 + j)
        req = Request(prompt=dom.sample_prompt(rng)[:16],
                      max_new_tokens=int(rng.integers(4, 9)))
        items.append((int(t), j % N, req))
    return items


def heavy_scenario(draft, target, dp, tp):
    """(csv_rows, json_section): the draft-lane sweep under an
    oversubscribed arrival burst at a fixed horizon and verify budget."""
    rows, section = [], {}
    for lanes in HEAVY_LANES:
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=16, s_max=6, cache_len=256,
                              paged_kv=True, kv_block_size=16, lanes=lanes)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(8), _heavy_workload(),
                                 dp, tp, rounds=HEAVY_ROUNDS)
        wall = time.perf_counter() - t0
        s = rep["summary"]
        total_tokens, per_server, p50, p95 = _drain_metrics(rep)
        fairness = round(jain(per_server), 4)
        rows.append((f"heavy_lanes{lanes}_total_accepted_tokens",
                     round(wall * 1e6 / max(1, s["rounds_run"]), 0),
                     total_tokens))
        rows.append((f"heavy_lanes{lanes}_jain_fairness", 0.0, fairness))
        rows.append((f"heavy_lanes{lanes}_p95_queue_wait_rounds", 0.0,
                     round(p95, 1)))
        section[f"lanes{lanes}"] = {
            "lanes": lanes,
            "total_accepted_tokens": total_tokens,
            "completed": s["completed"],
            "of_requests": HEAVY_K,
            "jain_fairness": fairness,
            "p50_queue_wait_rounds": round(p50, 1),
            "p95_queue_wait_rounds": round(p95, 1),
            "per_server_tokens": per_server.astype(int).tolist(),
            "rounds_run": s["rounds_run"],
            "round_latency_us": round(wall * 1e6 / max(1, s["rounds_run"]),
                                      1),
        }
    return rows, section


def overlap_scenario(draft, target, dp, tp):
    """(csv_rows, json_section): the round-graph overlap win on the heavy
    burst — the same oversubscribed workload served with the synchronous
    composed round vs the four-phase async pipeline
    (``GoodSpeedEngine(overlap=True)``).  Both modes emit identical
    accepted tokens (the deferred reconcile restores the exact
    synchronous state, pinned by tests/test_overlap.py); what changes is
    the ROUND PRICE: the simulated distributed round time collapses
    receive+verify to max(receive_t, verify_{t-1})
    (``LatencyModel.overlapped_round_time``), and the host pipeline
    enqueues all four phase dispatches before syncing.  Records, per
    mode: total accepted tokens, simulated round time (sum over the
    horizon of the mode's own pricing), and measured wall-clock/round;
    asserts overlap delivers >= the baseline's tokens at a strictly
    lower simulated round time, and that no round-phase jit ever
    retraced more than once for the engine's verify bucket
    (``round_trace_counts``)."""
    rows, section = [], {}
    for overlap in (False, True):
        tag = "overlap" if overlap else "sync"
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=16, s_max=6, cache_len=256,
                              paged_kv=True, kv_block_size=16,
                              overlap=overlap)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(8), _heavy_workload(),
                                 dp, tp, rounds=HEAVY_ROUNDS)
        wall = time.perf_counter() - t0
        s = rep["summary"]
        # retrace telemetry: one compiled variant per phase per bucket
        counts = eng.round_trace_counts()
        assert all(c <= 1 for c in counts.values()), \
            f"round phase retraced beyond its bucket: {counts}"
        sim_sync = sum(float(h.wall[0]) for h in rep["rounds"])
        sim = sum(float(h.wall_overlap) for h in rep["rounds"]) \
            if overlap else sim_sync
        total_tokens, _, _, p95 = _drain_metrics(rep)
        rows.append((f"overlap_{tag}_total_accepted_tokens",
                     round(wall * 1e6 / max(1, s["rounds_run"]), 0),
                     total_tokens))
        rows.append((f"overlap_{tag}_sim_round_time_ms", 0.0,
                     round(sim * 1e3 / max(1, s["rounds_run"]), 3)))
        section[tag] = {
            "overlap": overlap,
            "total_accepted_tokens": total_tokens,
            "completed": s["completed"],
            "of_requests": HEAVY_K,
            "sim_round_time_ms": round(sim * 1e3 / max(1, s["rounds_run"]),
                                       3),
            "sim_total_time_s": round(sim, 4),
            "round_latency_us": round(wall * 1e6 / max(1, s["rounds_run"]),
                                      1),
            "p95_queue_wait_rounds": round(p95, 1),
            "rounds_run": s["rounds_run"],
            "phase_trace_counts": counts,
        }
    assert section["overlap"]["total_accepted_tokens"] \
        >= section["sync"]["total_accepted_tokens"], section
    assert section["overlap"]["sim_total_time_s"] \
        < section["sync"]["sim_total_time_s"], section
    return rows, section


def _prefix_workload(seed: int = 9):
    """PREFIX_K requests sharing one PREFIX_SYS_LEN-token system prompt
    with short unique suffixes, bursting in over the first half of the
    horizon — the retrieval/chat pattern prefix caching targets."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, VOCAB, size=PREFIX_SYS_LEN).astype(np.int32)
    items, t = [], 0.0
    for j in range(PREFIX_K):
        t += rng.exponential(PREFIX_ROUNDS / (2.0 * PREFIX_K))
        suffix = rng.integers(1, VOCAB, size=PREFIX_SUF_LEN)
        req = Request(
            prompt=np.concatenate([system, suffix]).astype(np.int32),
            max_new_tokens=int(rng.integers(6, 12)))
        items.append((int(t), j % N, req))
    return items


def _prefix_admission_us(draft, target, dp, tp, prefix_cache: bool) -> float:
    """Median us to admit ONE request whose prompt shares a long
    registered prefix (PREFIX_ADMIT_SHARED of PREFIX_ADMIT_SHARED +
    PREFIX_ADMIT_SUF tokens).  With sharing on, only the suffix is
    prefilled; off, the full prompt is."""
    rng = np.random.default_rng(17)
    shared = rng.integers(1, VOCAB, size=PREFIX_ADMIT_SHARED)
    eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                          n_servers=4, C=12, s_max=6,
                          cache_len=PREFIX_ADMIT_CACHE_LEN,
                          paged_kv=True, kv_block_size=16,
                          prefix_cache=prefix_cache)
    state = eng.cold_start(jax.random.PRNGKey(0))
    donor = np.concatenate(
        [shared, rng.integers(1, VOCAB, size=PREFIX_ADMIT_SUF)]) \
        .astype(np.int32)
    state = eng._admit_rows(state, [0], {0: donor}, dp, tp)  # registers
    times = []
    for it in range(5):
        prompt = np.concatenate(
            [shared, rng.integers(1, VOCAB, size=PREFIX_ADMIT_SUF)]) \
            .astype(np.int32)
        t0 = time.perf_counter()
        state = eng._admit_rows(state, [1], {1: prompt}, dp, tp)
        jax.block_until_ready(jax.tree.leaves(
            (state.target_cache, state.draft_cache)))
        if it > 0:               # first call pays tracing/alloc warmup
            times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def prefix_scenario(draft, target, dp, tp):
    """(csv_rows, json_section): refcounted prefix caching on vs off.

    Admission microbench: sharing must be >= 2x cheaper at a >= 75%
    shared prefix (the chunk shrinks from the full prompt to the unique
    suffix).  Serve burst: the accepted-token stream must be IDENTICAL
    on vs off — sharing changes admission cost, never outputs."""
    rows, section = [], {}
    us = {tag: _prefix_admission_us(draft, target, dp, tp, on)
          for tag, on in (("shared_on", True), ("shared_off", False))}
    speedup = us["shared_off"] / max(us["shared_on"], 1e-9)
    frac = PREFIX_ADMIT_SHARED / (PREFIX_ADMIT_SHARED + PREFIX_ADMIT_SUF)
    assert speedup >= 2.0, \
        f"prefix sharing speedup {speedup:.2f}x < 2x at {frac:.0%} shared"
    serve = {}
    for tag, on in (("on", True), ("off", False)):
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=12, s_max=6, cache_len=256,
                              paged_kv=True, kv_block_size=16,
                              prefix_cache=on)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(15), _prefix_workload(),
                                 dp, tp, rounds=PREFIX_ROUNDS)
        wall = time.perf_counter() - t0
        s = rep["summary"]
        total_tokens, per_server, _, p95 = _drain_metrics(rep)
        ix = eng._prefix_index["target"] if on else None
        serve[tag] = {
            "prefix_cache": on,
            "total_accepted_tokens": total_tokens,
            "completed": s["completed"],
            "of_requests": PREFIX_K,
            "jain_fairness": round(jain(per_server), 4),
            "p95_queue_wait_rounds": round(p95, 1),
            "round_latency_us": round(wall * 1e6 / max(1, s["rounds_run"]),
                                      1),
            "rounds_run": s["rounds_run"],
            "index_hit_rate": round(ix.hits / max(1, ix.hits + ix.misses),
                                    3) if on else None,
        }
        rows.append((f"prefix_{tag}_total_accepted_tokens",
                     round(wall * 1e6 / max(1, s["rounds_run"]), 0),
                     total_tokens))
    # equivalence, not just non-regression: identical token stream
    assert serve["on"]["total_accepted_tokens"] \
        == serve["off"]["total_accepted_tokens"], serve
    assert serve["on"]["completed"] == serve["off"]["completed"], serve
    assert serve["on"]["jain_fairness"] == serve["off"]["jain_fairness"], \
        serve
    assert serve["on"]["index_hit_rate"] > 0.5, serve
    rows.append(("prefix_admit_shared_on_us",
                 round(us["shared_on"], 0), 0))
    rows.append(("prefix_admit_shared_off_us",
                 round(us["shared_off"], 0), 0))
    rows.append(("prefix_admission_speedup_x", 0.0, round(speedup, 2)))
    section.update({
        "shared_fraction": round(frac, 3),
        "admission_us": {"shared_on": round(us["shared_on"], 1),
                         "shared_off": round(us["shared_off"], 1),
                         "speedup_x": round(speedup, 2)},
        "serve": serve,
    })
    return rows, section


def _churn_workload(seed: int = 7):
    """CHURN_K medium requests arriving over the first half of the
    horizon, no server hints (goodput placement decides)."""
    rng = np.random.default_rng(seed)
    items, t = [], 0.0
    for j in range(CHURN_K):
        t += rng.exponential(CHURN_ROUNDS / (2.0 * CHURN_K))
        dom = SyntheticDomain(PAPER_DATASETS[j % len(PAPER_DATASETS)],
                              VOCAB, 130 + j)
        req = Request(prompt=dom.sample_prompt(rng)[:16],
                      max_new_tokens=int(rng.integers(6, 12)))
        items.append((int(t), None, req))
    return items


def _churn_plan():
    """The adversary: server 1 crashes mid-drain and rejoins late; server
    2 straggles hard enough (draft time x20) to blow the verify deadline
    every round of its window, so the health tracker downs it and its
    rejoin re-warms the estimator; server 3 suffers a short uplink-drop
    burst (one miss: SUSPECT haircut, then recovers)."""
    from repro.serving.faults import FaultEvent, FaultPlan

    return FaultPlan(events=(
        FaultEvent(round=10, kind="crash", server=1),
        FaultEvent(round=30, kind="rejoin", server=1),
        FaultEvent(round=8, kind="slowdown", server=2, factor=20.0,
                   duration=12),
        FaultEvent(round=24, kind="rejoin", server=2),
        FaultEvent(round=14, kind="drop", server=3, duration=1),
    ), deadline=CHURN_DEADLINE, k_down=2, migrate=True)


def _request_tokens(rep):
    """f64[K] tokens delivered per REQUEST across the whole workload —
    completed, in-flight, still-queued and lost alike.  Jain over THIS
    vector is the per-user fairness the churn scenario scores: a lost or
    starved request drags the index down even though per-server totals
    may look balanced."""
    mgr = rep["manager"]
    reqs = (mgr.completed + [r for r in mgr.active if r is not None]
            + list(mgr.arrivals) + [r for q in mgr.queues for r in q])
    return np.asarray([float(len(r.generated)) for r in reqs], np.float64)


def churn_scenario(draft, target, dp, tp):
    """(csv_rows, json_section): churn-tolerant serving vs no mitigation.

    Both runs serve the SAME workload under the SAME adversary script
    (``_churn_plan``); they differ only in the mitigation config.  The
    mitigated engine (finite verify deadline + health state machine +
    exact migration) must complete EVERY request (requests-lost = 0) and
    strictly beat the baseline (deadline=inf — one straggler stalls every
    round — and migrate=False — the crash destroys its seated requests)
    on both accepted tokens and per-request Jain fairness."""
    import dataclasses as _dc

    rows, section = [], {}
    plan = _churn_plan()
    configs = (
        ("mitigated", plan),
        ("no_mitigation", _dc.replace(plan, deadline=float("inf"),
                                      migrate=False)),
    )
    for tag, p in configs:
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=16, s_max=6, cache_len=256,
                              paged_kv=True, kv_block_size=16, lanes=2,
                              placement="goodput", greedy=True)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(13), _churn_workload(),
                                 dp, tp, rounds=CHURN_ROUNDS, faults=p)
        wall = time.perf_counter() - t0
        s = rep["summary"]
        per_req = _request_tokens(rep)
        total_tokens = int(per_req.sum())
        fairness = round(jain(per_req), 4)
        _, _, p50, p95 = _drain_metrics(rep)
        sim = sum(float(h.wall[0]) for h in rep["rounds"])
        rows.append((f"churn_{tag}_total_accepted_tokens",
                     round(wall * 1e6 / max(1, s["rounds_run"]), 0),
                     total_tokens))
        rows.append((f"churn_{tag}_jain_fairness", 0.0, fairness))
        rows.append((f"churn_{tag}_requests_lost", 0.0,
                     s["requests_lost"]))
        section[tag] = {
            "total_accepted_tokens": total_tokens,
            "completed": s["completed"],
            "of_requests": CHURN_K,
            "requests_lost": s["requests_lost"],
            "migrations": s["migrations"],
            "jain_fairness_per_request": fairness,
            "p50_queue_wait_rounds": round(p50, 1),
            "p95_queue_wait_rounds": round(p95, 1),
            "sim_round_time_ms": round(sim * 1e3 / max(1, s["rounds_run"]),
                                       3),
            "rounds_run": s["rounds_run"],
            "health": s["faults"],
        }
    mit, base = section["mitigated"], section["no_mitigation"]
    assert mit["requests_lost"] == 0, section
    assert mit["completed"] == CHURN_K, section
    assert mit["total_accepted_tokens"] > base["total_accepted_tokens"], \
        section
    assert mit["jain_fairness_per_request"] \
        > base["jain_fairness_per_request"], section
    return rows, section


def _merge_bench_json(update: dict) -> None:
    """Read-modify-write BENCH_serve.json so a single scenario run keeps
    the other sections' baselines.  A corrupt or truncated baseline file
    (killed run, merge conflict markers, partial write) must not abort a
    benchmark that just spent minutes collecting numbers: the bad file is
    backed up to ``BENCH_serve.json.corrupt`` and the merge restarts from
    a fresh dict."""
    data = {}
    if BENCH_JSON.exists():
        try:
            data = json.loads(BENCH_JSON.read_text())
            if not isinstance(data, dict):
                raise ValueError(f"expected a JSON object, "
                                 f"got {type(data).__name__}")
        except (ValueError, OSError) as e:
            backup = BENCH_JSON.with_suffix(".json.corrupt")
            BENCH_JSON.replace(backup)
            print(f"WARNING: {BENCH_JSON.name} is not valid JSON ({e}); "
                  f"backed it up to {backup.name} and starting fresh",
                  file=sys.stderr)
            data = {}
    data.update(update)
    BENCH_JSON.write_text(json.dumps(data, indent=2) + "\n")


def admission_cost(draft, target, dp, tp):
    """us per single-request admission at growing batch sizes.

    Warmup + median over repeats; each admission seats one fresh request
    into row 0 of a B-row engine (the production continuous-batching
    event).  us column = median admission cost; derived column = the same
    in ms.  The paged rows should stay ~flat while static rows grow
    with B."""
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, VOCAB, size=ADMIT_PROMPT_LEN).astype(np.int32)
    out = []
    for mode, paged in (("static", False), ("paged", True)):
        for b in ADMIT_BATCHES:
            eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                                  n_servers=b, C=12, s_max=6,
                                  cache_len=256, paged_kv=paged,
                                  kv_block_size=16)
            state = eng.cold_start(jax.random.PRNGKey(0))
            times = []
            for it in range(4):
                t0 = time.perf_counter()
                state = eng._admit_rows(state, [0], {0: prompt}, dp, tp)
                # block on the CACHES: pending has no data dependency on
                # the prefill, so syncing on it would time dispatch only
                jax.block_until_ready(jax.tree.leaves(
                    (state.target_cache, state.draft_cache)))
                if it > 0:       # first call pays tracing/alloc warmup
                    times.append((time.perf_counter() - t0) * 1e6)
            out.append((f"admit_one_request_{mode}_B{b}_us",
                        round(float(np.median(times)), 0),
                        round(float(np.median(times)) / 1e3, 1)))
    return out


def _models():
    draft = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              d_ff=128, vocab_size=VOCAB))
    target = Model(get_reduced("qwen3-8b", num_layers=2, d_model=128,
                               num_heads=4, num_kv_heads=2, head_dim=32,
                               d_ff=256, vocab_size=VOCAB))
    return (draft, target, draft.init(jax.random.PRNGKey(0)),
            target.init(jax.random.PRNGKey(1)))


def run():
    from benchmarks.paged_decode_bench import collect as paged_decode_numbers

    # microbench FIRST: its µs-scale numbers are noise-sensitive and the
    # engine serves below leave a lot of compiled/allocated state behind
    microbench = paged_decode_numbers()
    draft, target, dp, tp = _models()
    admit_rows = list(admission_cost(draft, target, dp, tp))
    rows = list(admit_rows)
    serve_json = {}
    for pol, backend, paged in SERVE_CONFIGS:
        tag = pol if backend == "jnp" else \
            f"{pol}_{backend}" + ("_paged" if paged else "")
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=12, s_max=6, cache_len=256,
                              policy=pol, draft_temps=(1.0, 1.3, 2.0, 2.8),
                              attn_backend=backend, paged_kv=paged,
                              kv_block_size=16)
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(2), _workload(), dp, tp,
                                 rounds=ROUNDS)
        s = rep["summary"]
        us_round = (time.perf_counter() - t0) * 1e6 / max(1, s["rounds_run"])
        rows.append((f"serve_requests_{tag}_completed_of_{K}", 0.0,
                     s["completed"]))
        rows.append((f"serve_requests_{tag}_tokens_per_round",
                     round(us_round, 0), round(s["tokens_per_round"], 2)))
        rows.append((f"serve_requests_{tag}_mean_latency_rounds", 0.0,
                     round(s["mean_latency_rounds"], 2)))
        rows.append((f"serve_requests_{tag}_requests_per_round", 0.0,
                     round(s["requests_per_round"], 3)))
        serve_json[tag] = {
            "policy": pol, "attn_backend": backend, "paged_kv": paged,
            "rounds_run": s["rounds_run"],
            "round_latency_us": round(us_round, 1),
            "tokens_per_round": round(s["tokens_per_round"], 3),
            "mean_latency_rounds": round(s["mean_latency_rounds"], 3),
            "completed": s["completed"],
        }
    skew_rows, skew_json = skewed_scenario(draft, target, dp, tp)
    rows.extend(skew_rows)
    heavy_rows, heavy_json = heavy_scenario(draft, target, dp, tp)
    rows.extend(heavy_rows)
    ov_rows, ov_json = overlap_scenario(draft, target, dp, tp)
    rows.extend(ov_rows)
    prefix_rows, prefix_json = prefix_scenario(draft, target, dp, tp)
    rows.extend(prefix_rows)
    churn_rows, churn_json = churn_scenario(draft, target, dp, tp)
    rows.extend(churn_rows)
    _merge_bench_json({
        "admission_cost_us": {name: us for name, us, _ in admit_rows},
        "serve": serve_json,
        "placement_skewed": skew_json,
        "lanes_heavy": heavy_json,
        "overlap": ov_json,
        "prefix_shared": prefix_json,
        "churn": churn_json,
        "paged_decode_microbench": {
            f"capacity_{cap}": r for cap, r in microbench.items()
        },
    })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario",
                    choices=("all", "skewed", "heavy", "overlap", "prefix",
                             "churn"),
                    default="all",
                    help="'skewed' runs only the placement-policy sweep, "
                    "'heavy' only the draft-lane sweep, 'overlap' only "
                    "the round-graph overlap comparison, 'prefix' only "
                    "the prefix-caching on/off comparison, 'churn' only "
                    "the fault-injection mitigated-vs-baseline comparison; "
                    "each merges its section into BENCH_serve.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.scenario == "skewed":
        rows, section = skewed_scenario(*_models())
        _merge_bench_json({"placement_skewed": section})
    elif args.scenario == "heavy":
        rows, section = heavy_scenario(*_models())
        _merge_bench_json({"lanes_heavy": section})
    elif args.scenario == "overlap":
        rows, section = overlap_scenario(*_models())
        _merge_bench_json({"overlap": section})
    elif args.scenario == "prefix":
        rows, section = prefix_scenario(*_models())
        _merge_bench_json({"prefix_shared": section})
    elif args.scenario == "churn":
        rows, section = churn_scenario(*_models())
        _merge_bench_json({"churn": section})
    else:
        rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
