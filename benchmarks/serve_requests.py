"""Request-lifecycle serving benchmark: continuous batching under load.

Drives ``GoodSpeedEngine.serve_requests`` with a Poisson-ish arrival
process (deterministic rng): K requests arrive over the first half of the
horizon, exponential-ish inter-arrival gaps, round-robin server affinity,
heterogeneous per-request token budgets.  Reports request throughput
(completions and tokens per round) and mean completion latency (arrival ->
finish, in rounds) for the goodspeed policy vs the fixed-S baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import PAPER_DATASETS, SyntheticDomain
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine
from repro.serving.request import Request

N, K, ROUNDS, VOCAB = 4, 16, 80, 256


def _workload(seed: int = 0):
    """(arrival_round, server, Request) with exp-ish inter-arrival gaps."""
    rng = np.random.default_rng(seed)
    items, t = [], 0.0
    for j in range(K):
        t += rng.exponential(ROUNDS / (2.0 * K))
        dom = SyntheticDomain(PAPER_DATASETS[j % len(PAPER_DATASETS)],
                              VOCAB, j)
        req = Request(prompt=dom.sample_prompt(rng)[:16],
                      max_new_tokens=int(rng.integers(6, 14)))
        items.append((int(t), j % N, req))
    return items


def run():
    draft = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              d_ff=128, vocab_size=VOCAB))
    target = Model(get_reduced("qwen3-8b", num_layers=2, d_model=128,
                               num_heads=4, num_kv_heads=2, head_dim=32,
                               d_ff=256, vocab_size=VOCAB))
    dp = draft.init(jax.random.PRNGKey(0))
    tp = target.init(jax.random.PRNGKey(1))
    rows = []
    for pol in ("goodspeed", "fixed"):
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=12, s_max=6, cache_len=256,
                              policy=pol, draft_temps=(1.0, 1.3, 2.0, 2.8))
        t0 = time.perf_counter()
        rep = eng.serve_requests(jax.random.PRNGKey(2), _workload(), dp, tp,
                                 rounds=ROUNDS)
        s = rep["summary"]
        us_round = (time.perf_counter() - t0) * 1e6 / max(1, s["rounds_run"])
        rows.append((f"serve_requests_{pol}_completed_of_{K}", 0.0,
                     s["completed"]))
        rows.append((f"serve_requests_{pol}_tokens_per_round",
                     round(us_round, 0), round(s["tokens_per_round"], 2)))
        rows.append((f"serve_requests_{pol}_mean_latency_rounds", 0.0,
                     round(s["mean_latency_rounds"], 2)))
        rows.append((f"serve_requests_{pol}_requests_per_round", 0.0,
                     round(s["requests_per_round"], 3)))
    return rows
