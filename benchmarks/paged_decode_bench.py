"""Paged decode attention microbench: block-table-native vs gather path.

Fixes the pool OCCUPANCY (tokens actually held per row) and grows the
logical CAPACITY (table width M, pool sized to match).  The gather path
(``attention.paged_dot_attention``) materializes the full [B, M*bs, ...]
logical view through the block table before attending, so its per-token
decode cost grows with capacity even when the extra blocks are
unallocated.  The block-table-native path (``kernels.paged_decode``)
walks only the allocated block prefix — cost tracks occupancy and stays
~flat in capacity.  This is the acceptance microbench for the
``attn_backend="kernel"`` serving hot path; the numbers land in
``BENCH_serve.json`` via ``benchmarks.serve_requests``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_decode import paged_flash_decode
from repro.models.attention import paged_dot_attention
from repro.serving import kv_cache as kc

B, KV, G, HD, BS = 4, 2, 2, 64, 16
H = KV * G
OCCUPANCY = 96                       # tokens held per row (fixed)
CAPACITIES = (128, 512, 2048)        # logical slots per row (grows)
REPEATS = 30

_CACHE: dict | None = None


def _time(fn, *args) -> float:
    """Median wall us of a jit'd call (warmup excluded)."""
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def collect() -> dict:
    """{capacity: {"gather_us": .., "block_native_us": ..}} at fixed
    occupancy (cached: serve_requests embeds the same numbers in
    BENCH_serve.json)."""
    global _CACHE
    if _CACHE is not None:
        return _CACHE
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, 1, H, HD)), jnp.float32)
    vals = (jnp.asarray(rng.normal(size=(B, OCCUPANCY, KV, HD)),
                        jnp.float32),
            jnp.asarray(rng.normal(size=(B, OCCUPANCY, KV, HD)),
                        jnp.float32))
    lengths = jnp.full((B,), OCCUPANCY, jnp.int32)
    gather = jax.jit(lambda q_, c, p: paged_dot_attention(q_, c, p))
    native = jax.jit(lambda q_, c, p: paged_flash_decode(q_, c, p,
                                                         impl="auto"))
    out = {}
    for cap in CAPACITIES:
        cache = kc.init_paged_attn_cache(B, cap, KV, HD, jnp.float32, BS)
        cache = kc.write_prefill(cache, vals, lengths)
        q_pos = cache.next_pos[:, None]
        out[cap] = {
            "gather_us": round(_time(gather, q, cache, q_pos), 1),
            "block_native_us": round(_time(native, q, cache, q_pos), 1),
        }
    _CACHE = out
    return out


def run():
    rows = []
    for cap, r in collect().items():
        ratio = round(r["gather_us"] / max(r["block_native_us"], 1e-9), 2)
        rows.append((f"paged_decode_gather_cap{cap}_us", r["gather_us"],
                     f"occ={OCCUPANCY}"))
        rows.append((f"paged_decode_block_native_cap{cap}_us",
                     r["block_native_us"], f"speedup={ratio}x"))
    return rows
