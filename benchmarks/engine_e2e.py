"""End-to-end engine benchmark: real-model GoodSpeed rounds (reduced dims).

Measures per-round latency of the full Algorithm-1 loop (draft decode steps
+ batched verification + scheduling) for GoodSpeed vs Fixed-S, and reports
the realized-goodput advantage.  This is the miniature of the paper's
testbed: N=4 draft servers, shared small draft model with heterogeneous
temperatures, a 4-layer target."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticDomain
from repro.models import Model
from repro.serving.engine import GoodSpeedEngine

N, ROUNDS = 4, 24


def _prompts(vocab):
    rng = np.random.default_rng(0)
    return [SyntheticDomain("alpaca", vocab, i).sample_prompt(rng)[:12]
            for i in range(N)]


def run():
    import time
    draft = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              d_ff=128, vocab_size=256))
    target = Model(get_reduced("qwen3-8b", num_layers=2, d_model=128,
                               num_heads=4, num_kv_heads=2, head_dim=32,
                               d_ff=256, vocab_size=256))
    dp = draft.init(jax.random.PRNGKey(0))
    tp = target.init(jax.random.PRNGKey(1))
    rows = []
    goodput = {}
    for pol in ("goodspeed", "fixed"):
        eng = GoodSpeedEngine(draft_model=draft, target_model=target,
                              n_servers=N, C=12, s_max=6, cache_len=256,
                              policy=pol, draft_temps=(1.0, 1.0, 3.5, 3.5))
        t0 = time.perf_counter()
        hist = eng.serve(jax.random.PRNGKey(2), _prompts(256), dp, tp,
                         rounds=ROUNDS)
        us = (time.perf_counter() - t0) * 1e6 / ROUNDS
        tot = float(np.mean([h.realized.sum() for h in hist]))
        goodput[pol] = tot
        rows.append((f"e2e_round_{pol}_tokens_per_round", round(us, 0),
                     round(tot, 2)))
    rows.append(("e2e_goodspeed_vs_fixed_tokens_pct", 0.0, round(
        100.0 * (goodput["goodspeed"] / goodput["fixed"] - 1.0), 2)))
    return rows
