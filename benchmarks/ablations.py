"""Ablations beyond the paper's main tables.

1. utility-family sweep (alpha-fair: 0=throughput, 1=log/proportional as in
   the paper, 2=more egalitarian): total goodput vs Jain fairness index —
   shows exactly what the log-utility choice buys.
2. budget sweep: C in {8..64} — goodput saturates at the roofline knee, the
   paper's motivation for choosing C there.
3. top-k draft-distribution truncation (beyond-paper): uplink payload and
   receive-time reduction vs the paper's full-distribution protocol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import jain, time_call
from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.core.latency import LatencyModel
from repro.core.utility import UtilitySpec
from repro.data.pipeline import make_workload

N, ROUNDS = 8, 500


def run():
    rows = []
    _, alphas = make_workload(N, 32000, ROUNDS, seed=3)

    # 1. utility-family sweep
    for ua in (0.0, 1.0, 2.0):
        coord = Coordinator(
            n=N, C=20, policy="goodspeed", utility=UtilitySpec(alpha=ua),
            estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                       beta=StepSchedule(0.1)))
        us, (_, logs) = time_call(
            lambda c=coord: c.simulate_analytic(jax.random.PRNGKey(4),
                                                alphas), iters=1, warmup=1)
        avg = np.asarray(logs.realized[-200:]).mean(axis=0)
        rows.append((f"ablate_utility_alpha{ua:g}_total_goodput",
                     us / ROUNDS, round(float(avg.sum()), 3)))
        rows.append((f"ablate_utility_alpha{ua:g}_jain_fairness",
                     us / ROUNDS, round(jain(avg), 4)))

    # 2. budget sweep
    for c in (8, 16, 32, 64):
        coord = Coordinator(
            n=N, C=c, policy="goodspeed",
            estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                       beta=StepSchedule(0.1)))
        _, logs = coord.simulate_analytic(jax.random.PRNGKey(5), alphas)
        avg = float(np.asarray(logs.realized[-200:]).sum(axis=1).mean())
        rows.append((f"ablate_budget_C{c}_tokens_per_round", 0.0,
                     round(avg, 2)))

    # 3. top-k truncation (151936-token vocab, S=[4]*8)
    S = jnp.full((N,), 4, jnp.int32)
    jit = jnp.zeros((N,))
    for k in (0, 1024, 64):
        lm = LatencyModel(probs_topk=k)
        recv = float(lm.receive_time(S, 151936, jit))
        rows.append((f"ablate_topk_{k or 'full'}_receive_s", 0.0,
                     round(recv, 4)))
    return rows
