"""Paper Fig. 3 — wall-time decomposition (receive / verify / send).

Simulates 600 rounds per policy with the discrete-event latency model
(TPU-adapted constants) and reports each policy's mean per-round wall time
split, plus GoodSpeed's verify-time saving vs Fixed-S (paper: ~5%) and
Random-S's total-time penalty (paper: 5-25%)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import time_call
from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.data.pipeline import make_workload

N, C, ROUNDS = 8, 20, 600


def _run_policy(policy, alphas):
    coord = Coordinator(
        n=N, C=C, policy=policy, max_new_tokens=150,  # paper: 150-token cfg
        estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                   beta=StepSchedule(0.1)))
    us, (_, logs) = time_call(
        lambda: coord.simulate_analytic(jax.random.PRNGKey(1), alphas),
        iters=3, warmup=1)
    wall = np.asarray(logs.wall)  # [T, 4] total/receive/verify/send
    return us, wall.mean(axis=0)


def run():
    import numpy as _np
    rows = []
    # (a) paper-like workload: clients with SIMILAR acceptance rates (the
    # paper's clients share model families; its Fig 3 shows GoodSpeed total
    # comparable to Fixed-S, which requires near-uniform allocations)
    rng = _np.random.default_rng(0)
    homog = jnp_like = _np.clip(
        0.7 + 0.05 * rng.standard_normal((ROUNDS, N)), 0.05, 0.95
    ).astype(_np.float32)
    # (b) heterogeneous edge workload (our synthetic dataset mix)
    _, hetero = make_workload(N, 32000, ROUNDS, seed=1)

    for tag, alphas in (("homog", homog), ("hetero", _np.asarray(hetero))):
        import jax.numpy as jnp
        walls = {}
        for pol in ("goodspeed", "fixed", "random"):
            us, mean_wall = _run_policy(pol, jnp.asarray(alphas))
            walls[pol] = mean_wall
            total, recv, ver, send = mean_wall
            rows.append((f"fig3_{tag}_wall_{pol}_total_s", us / ROUNDS,
                         round(float(total), 5)))
            rows.append((f"fig3_{tag}_wall_{pol}_recv_frac", us / ROUNDS,
                         round(float(recv / total), 4)))
            rows.append((f"fig3_{tag}_wall_{pol}_verify_frac", us / ROUNDS,
                         round(float(ver / total), 4)))
            rows.append((f"fig3_{tag}_wall_{pol}_send_frac", us / ROUNDS,
                         round(float(send / total), 4)))
        rows.append((f"fig3_{tag}_random_vs_fixed_total_pct", 0.0, round(
            100.0 * float(walls["random"][0] / walls["fixed"][0] - 1.0), 2)))
        rows.append((f"fig3_{tag}_goodspeed_vs_fixed_total_pct", 0.0, round(
            100.0 * float(walls["goodspeed"][0] / walls["fixed"][0] - 1.0), 2)))
        rows.append((f"fig3_{tag}_goodspeed_vs_fixed_verify_pct", 0.0, round(
            100.0 * float(walls["goodspeed"][2] / walls["fixed"][2] - 1.0), 2)))
    return rows
