"""Paper Fig. 4 — convergence of U(x_bar(T)) for GoodSpeed vs baselines.

Reports the converged utility per policy, GoodSpeed's gap to the fluid
optimum U(x*), and the stabilization round (first T after which the running
utility stays within 2% of its final value — paper reports ~400-600)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.coordinator import Coordinator
from repro.core.estimator import GoodputEstimator, StepSchedule
from repro.core.fluid import optimal_goodput
from repro.core.utility import UtilitySpec
from repro.data.pipeline import make_workload

N, C, ROUNDS = 8, 20, 900


def _running_utility(realized):
    u = UtilitySpec(alpha=1.0)
    csum = np.cumsum(realized, axis=0)
    denom = np.arange(1, len(realized) + 1)[:, None]
    running = csum / denom
    return np.array([float(u.value(jnp.asarray(r))) for r in running])


def run():
    _, alphas = make_workload(N, 32000, ROUNDS, seed=2)
    mean_alpha = jnp.asarray(np.asarray(alphas).mean(axis=0))
    _, x_star = optimal_goodput(mean_alpha, C)
    u_star = float(UtilitySpec(alpha=1.0).value(x_star))

    rows = []
    finals = {}
    for pol in ("goodspeed", "fixed", "random"):
        coord = Coordinator(
            n=N, C=C, policy=pol,
            estimator=GoodputEstimator(eta=StepSchedule(0.3),
                                       beta=StepSchedule(0.1)))
        us, (_, logs) = time_call(
            lambda c=coord: c.simulate_analytic(jax.random.PRNGKey(2),
                                                alphas), iters=1, warmup=1)
        traj = _running_utility(np.asarray(logs.realized))
        finals[pol] = traj[-1]
        rows.append((f"fig4_utility_{pol}", us / ROUNDS,
                     round(float(traj[-1]), 4)))
        if pol == "goodspeed":
            tol = 0.02 * abs(traj[-1])
            stable = np.where(np.abs(traj - traj[-1]) > tol)[0]
            stab_round = int(stable[-1]) + 1 if len(stable) else 0
            rows.append(("fig4_stabilization_round", us / ROUNDS,
                         stab_round))
    rows.append(("fig4_gap_to_fluid_opt", 0.0,
                 round(u_star - finals["goodspeed"], 4)))
    rows.append(("fig4_goodspeed_minus_fixed", 0.0,
                 round(finals["goodspeed"] - finals["fixed"], 4)))
    rows.append(("fig4_goodspeed_minus_random", 0.0,
                 round(finals["goodspeed"] - finals["random"], 4)))
    return rows
