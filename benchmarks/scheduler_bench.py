"""Scheduler microbenchmark (supports paper Table I deployment configs).

Times GOODSPEED-SCHED solves at the paper's configurations (N=4, C=24/28;
N=8, C=16/20) and at production scale (N=256 draft servers), for both the
exact greedy and the threshold-bisection solver, plus the TPU-adapted
budget derivation C* for each assigned verify-model architecture."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.configs import ARCHITECTURES
from repro.core.budget import derive_budget
from repro.core.scheduler import solve_greedy, solve_threshold

CONFIGS = [(4, 24), (4, 28), (8, 16), (8, 20), (64, 256), (256, 1024)]


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for n, c in CONFIGS:
        alpha = jax.random.uniform(key, (n,), minval=0.1, maxval=0.95)
        w = jnp.ones((n,))
        us_t, out_t = time_call(
            lambda a=alpha, ww=w, cc=c: solve_threshold(a, ww, cc), iters=20)
        rows.append((f"sched_threshold_N{n}_C{c}", round(us_t, 1),
                     int(jnp.sum(out_t.S))))
        if c <= 64:
            us_g, out_g = time_call(
                lambda a=alpha, ww=w, cc=c: solve_greedy(a, ww, cc), iters=20)
            rows.append((f"sched_greedy_N{n}_C{c}", round(us_g, 1),
                         round(float(out_g.objective), 3)))

    # Table-I analogue: v5e-adapted budget C* per verify model
    for name in ("qwen3-8b", "stablelm-12b", "deepseek-v2-lite-16b"):
        cfg = ARCHITECTURES[name]
        kvb = (cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2
               * cfg.num_layers)  # bytes per token of KV, bf16
        c_star = derive_budget(n_servers=8, params=cfg.param_count(),
                               kv_bytes_per_token=kvb, max_prefix_len=2048,
                               chips=8)
        rows.append((f"tableI_budget_{name}_8chip", 0.0, c_star))
    return rows
