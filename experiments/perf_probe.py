"""Fast perf-iteration probe: per-group calibrated costs WITHOUT the full
scanned compile.  Usage:
  PYTHONPATH=src python experiments/perf_probe.py <arch> <shape>
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
import sys

from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.distributed.sharding import SERVE_RULES, TRAIN_RULES
from repro.configs import INPUT_SHAPES, get_config
import dataclasses

arch, shape = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
cfg = dataclasses.replace(cfg, dtype="bfloat16", param_dtype="bfloat16")
if len(sys.argv) > 3 and sys.argv[3] == "--ep":
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, shard_map_ep=True))
    print("(shard_map expert parallelism ON)")
mesh = make_production_mesh()
kind = INPUT_SHAPES[shape][2]
rules = TRAIN_RULES if kind == "train" else SERVE_RULES
flops, bytes_acc, coll, meta = dryrun._calibrated_costs(cfg, shape, mesh, rules)
print(f"arch={arch} shape={shape}")
print(f"  flops/dev          {flops:.4e}  ({flops/197e12:.3f}s)")
print(f"  bytes/dev          {bytes_acc:.4e}  ({bytes_acc/819e9:.3f}s)")
print(f"  collective B/dev   {coll:.4e}  ({coll/50e9:.3f}s)")
print(f"  meta {meta}")
