import os
import sys

import pytest

# make `tests.proptest` and `benchmarks.*` importable regardless of how
# pytest is invoked (the documented command is `PYTHONPATH=src pytest tests/`)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    # Registered (not auto-skipped) marker: the ~2-minute dry-run compile
    # tests stay in tier-1 by default; deselect with `-m "not slow"`.
    config.addinivalue_line(
        "markers", "slow: long-running compile/integration tests "
                   "(on by default; deselect with -m 'not slow')")


# ---------------------------------------------------------------------------
# Shared serving-trace harness: the ACCEPTANCE mixed admit/retire/EOS
# workload that every serving-equivalence suite replays (paged vs static
# caches, jnp vs kernel backends, placement policies vs the legacy
# per-server FIFO).  One session-scoped model pair keeps params and jit
# caches shared across the suites.
# ---------------------------------------------------------------------------

MIXED_TRACE_VOCAB = 64


def mixed_trace_requests(k=7, seed=11, max_new=5, vocab=MIXED_TRACE_VOCAB):
    """The mixed workload: k requests, EOS on every odd index so the trace
    exercises cap-retirement, EOS-retirement, and queued successors."""
    import numpy as np

    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(1, vocab, size=8).astype(np.int32),
                    max_new_tokens=max_new,
                    eos_token=(4 if i % 2 else -1)) for i in range(k)]


def generated_seqs(rep):
    """Accepted-token sequences of a serve_requests report, ordered by
    request id — the byte-comparable equivalence artifact."""
    return [r["generated"] for r in
            sorted(rep["requests"], key=lambda r: r["request_id"])]


@pytest.fixture(scope="session")
def serve_pair():
    """Reduced draft/target models + params for the serving suites."""
    import jax

    from repro.configs import get_reduced
    from repro.models import Model

    dm = Model(get_reduced("olmo-1b", num_layers=2, d_model=64,
                           num_heads=2, num_kv_heads=2, head_dim=32,
                           d_ff=128, vocab_size=MIXED_TRACE_VOCAB))
    tm = Model(get_reduced("qwen3-8b", num_layers=2, d_model=128,
                           num_heads=4, num_kv_heads=2, head_dim=32,
                           d_ff=256, vocab_size=MIXED_TRACE_VOCAB))
    return dm, tm, dm.init(jax.random.PRNGKey(0)), \
        tm.init(jax.random.PRNGKey(1))


@pytest.fixture(scope="session")
def mixed_trace(serve_pair):
    """Callable fixture: run the mixed workload through serve_requests for
    one engine configuration and return the full report.  Engine kwargs
    override the defaults (2 servers, C=8, s_max=4, cache_len=128)."""
    import jax

    from repro.serving.engine import GoodSpeedEngine

    dm, tm, dp, tp = serve_pair

    def run(*, requests=7, rounds=60, manager=None, expect_completed=7,
            workload=None, **engine_kw):
        kw = dict(draft_model=dm, target_model=tm, n_servers=2, C=8,
                  s_max=4, cache_len=128, kv_block_size=16)
        kw.update(engine_kw)
        eng = GoodSpeedEngine(**kw)
        rep = eng.serve_requests(
            jax.random.PRNGKey(0),
            workload if workload is not None
            else mixed_trace_requests(requests),
            dp, tp, rounds=rounds, manager=manager)
        if expect_completed is not None:
            assert rep["summary"]["completed"] == expect_completed
        return rep

    return run
