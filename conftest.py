import os
import sys

# make `tests.proptest` and `benchmarks.*` importable regardless of how
# pytest is invoked (the documented command is `PYTHONPATH=src pytest tests/`)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
