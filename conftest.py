import os
import sys

# make `tests.proptest` and `benchmarks.*` importable regardless of how
# pytest is invoked (the documented command is `PYTHONPATH=src pytest tests/`)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    # Registered (not auto-skipped) marker: the ~2-minute dry-run compile
    # tests stay in tier-1 by default; deselect with `-m "not slow"`.
    config.addinivalue_line(
        "markers", "slow: long-running compile/integration tests "
                   "(on by default; deselect with -m 'not slow')")
