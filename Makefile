# Convenience targets; the documented tier-1 command is
#   PYTHONPATH=src python -m pytest -x -q

test:
	PYTHONPATH=src python -m pytest -x -q

docs-check:
	PYTHONPATH=src python -m scripts.check_docs

# kernel packages standalone (interpret mode on CPU): Pallas kernels and
# fused refs vs their jnp oracles, plus the attn_backend e2e equivalence
kernels-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_kernels.py tests/test_paged_kernel.py

# global-admission layer standalone: placement property suite (random
# arrival traces x policies), the static-vs-legacy equivalence traces,
# the fairness regression, and the request-manager lifecycle tests
placement-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_placement.py tests/test_sampling_requests.py

# draft-lane layer standalone: the split_lanes water-filling properties,
# the estimator hold-on-unobserved regression, lane-manager conservation,
# and the engine-level lanes=1 golden-trace equivalence + lanes=2 pins
lanes-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_lanes.py tests/test_scheduler.py

# churn layer standalone: fault-plan/health-tracker state machine, the
# in-graph verify-deadline drop semantics, migration byte-equivalence
# under greedy decoding, paged-block reclamation on crash, and manager
# conservation under random fault plans
churn-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_faults.py

# round-graph layer standalone: verify_bucket table properties, the
# discard_tail/snapshot_alloc_flag deferred-rollback primitives, the
# overlap-vs-sync state identity + golden-trace equivalence, and the
# LatencyModel round decomposition / overlapped-round pins
overlap-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_overlap.py tests/test_budget_latency.py

# prefix-caching layer standalone: the PrefixIndex host map, refcount /
# COW / attach primitives, the admission pre-check property sweep, the
# chunk-write overflow regression, and the shared-prefix serving
# equivalence matrix (jnp x kernel, sync x overlap, lanes 1-2)
prefix-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_prefix_cache.py

# jit-discipline layer: jaxlint self-hosted over src/ at zero findings
# (the CI gate), the linter's own fixture suite, and the runtime
# guards (retrace budget + transfer fence)
lint-check:
	PYTHONPATH=src python -m repro.analysis.jaxlint src
	PYTHONPATH=src python -m pytest -x -q tests/test_jaxlint.py tests/test_trace_guard.py

bench:
	PYTHONPATH=src python -m benchmarks.run

.PHONY: test docs-check kernels-check placement-check lanes-check \
	churn-check overlap-check prefix-check lint-check bench
