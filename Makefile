# Convenience targets; the documented tier-1 command is
#   PYTHONPATH=src python -m pytest -x -q

test:
	PYTHONPATH=src python -m pytest -x -q

docs-check:
	PYTHONPATH=src python -m scripts.check_docs

bench:
	PYTHONPATH=src python -m benchmarks.run

.PHONY: test docs-check bench
