# Convenience targets; the documented tier-1 command is
#   PYTHONPATH=src python -m pytest -x -q

test:
	PYTHONPATH=src python -m pytest -x -q

docs-check:
	PYTHONPATH=src python -m scripts.check_docs

# kernel packages standalone (interpret mode on CPU): Pallas kernels and
# fused refs vs their jnp oracles, plus the attn_backend e2e equivalence
kernels-check:
	PYTHONPATH=src python -m pytest -x -q tests/test_kernels.py tests/test_paged_kernel.py

bench:
	PYTHONPATH=src python -m benchmarks.run

.PHONY: test docs-check kernels-check bench
